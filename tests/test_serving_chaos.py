"""Chaos-hardened serving fleet (DESIGN.md §14).

Covers the named serving error family, bounded-queue fair shedding with
the DRR starvation bound, per-stream fault injection with
requeue-not-lose delivery, the serve-driven degradation ladder (descent
and hysteresis recovery), device-kill failover bit-identity (subprocess
with fake devices), server checkpoint/restore with exactly-once frame
accounting, and the zero-fault pin: an inert chaos plane changes nothing.
"""

import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.camera.offload.link import ETH_25G_LINK, GilbertElliott
from repro.camera.serve import (ChaosEngine, ChaosSpec, ServeConfig,
                                ServeError, StreamDrainingError,
                                StreamingServer, UnknownStreamError)

ALWAYS_LOST = GilbertElliott(p_gb=1.0, p_bg=0.0, loss_bad=1.0,
                             loss_good=1.0)
SOMETIMES_LOST = GilbertElliott(p_gb=0.3, p_bg=0.3, loss_bad=0.9,
                                loss_good=0.0)


@pytest.fixture(scope="module")
def fa_setup():
    from benchmarks.workloads import fa_cascade, fa_scan
    from repro.camera.face_nn import train_face_nn
    from repro.camera.pipelines import FaceAuthExecutor
    from repro.camera.synthetic import face_dataset, security_video

    frames, _truth = security_video(n_frames=10, motion_frames=5, seed=1)
    casc = fa_cascade(smoke=True)
    X, y, _ = face_dataset(n_per_class=80, seed=3)
    nn = train_face_nn(X, y, steps=60)
    sf, st, ad = fa_scan(True)
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                          scale_factor=sf, step=st, adaptive=ad)
    ex.calibrate(frames)
    return ex, frames, ex(jnp.asarray(frames))


def _motion_pair(frames, base):
    motion = np.asarray(base.motion)
    i = int(np.argmax(motion[1:])) + 1
    assert motion[i]
    return np.stack([frames[i - 1], frames[i]])


def _server(ex, *, chunk=2, capacity=2, chaos=None, link=None, **kw):
    kw.setdefault("max_queue_s", 100.0)
    cfg = ServeConfig(chunk=chunk, capacity=capacity, tick_s=1.0, **kw)
    return StreamingServer(ex, link=link, config=cfg, chaos=chaos)


class _ScriptedInjector:
    """Stands in for a FaultInjector: scripted attempt outcomes."""

    def __init__(self, outcomes):
        self.outcomes = list(outcomes)
        self.brownout = None

    def attempt(self, t):
        return self.outcomes.pop(0) if self.outcomes else "ok"


# ---------------------------------------------------------------------------
# named error family (satellites 1 + 2)
# ---------------------------------------------------------------------------


class TestServeErrors:
    def test_unknown_sid_lists_known_streams(self, fa_setup):
        ex, frames, base = fa_setup
        srv = _server(ex)
        srv.register("cam-a", fps=1.0)
        srv.register("cam-b", fps=1.0)
        with pytest.raises(UnknownStreamError, match="cam-a"):
            srv.enqueue("ghost", frames[0], t=0.0)
        with pytest.raises(UnknownStreamError, match="'ghost'"):
            srv.unregister("ghost")
        # the named family subclasses ValueError: pre-§14 callers keep
        # catching what they caught
        with pytest.raises(ValueError):
            srv.enqueue("ghost", frames[0], t=0.0)

    def test_enqueue_validates_shape_and_dtype(self, fa_setup):
        ex, frames, base = fa_setup
        srv = _server(ex)
        srv.register("a", fps=1.0)
        with pytest.raises(ServeError, match="shape"):
            srv.enqueue("a", frames[0][:-1], t=0.0)
        with pytest.raises(ServeError, match="castable"):
            srv.enqueue("a", np.array([["x"] * ex.det.grid.w]
                                      * ex.det.grid.h), t=0.0)
        # a valid frame still enqueues (validation is not over-strict)
        assert srv.enqueue("a", frames[0].astype(np.float64), t=0.0) == 0

    def test_reregister_while_draining_is_named_error(self, fa_setup):
        ex, frames, base = fa_setup
        srv = _server(ex)
        srv.register("a", fps=1.0)
        srv.enqueue("a", frames[0], t=0.0)
        assert srv.unregister("a") == 1
        with pytest.raises(StreamDrainingError, match="draining"):
            srv.register("a", fps=1.0)
        with pytest.raises(StreamDrainingError):
            srv.enqueue("a", frames[1], t=0.5)
        srv.tick(1.0)                       # drain completes, sid reaped
        assert "a" not in srv.streams
        srv.register("a", fps=1.0)          # now re-registration is clean
        assert "a" in srv.streams

    def test_enqueue_after_drain_completes_is_unknown_stream(self, fa_setup):
        # regression: the reaped sid used to surface as a bare KeyError
        ex, frames, base = fa_setup
        srv = _server(ex)
        srv.register("a", fps=1.0)
        srv.enqueue("a", frames[0], t=0.0)
        srv.unregister("a")
        srv.tick(1.0)
        with pytest.raises(UnknownStreamError, match="'a'"):
            srv.enqueue("a", frames[1], t=2.0)

    def test_double_register_still_valueerror(self, fa_setup):
        ex, frames, base = fa_setup
        srv = _server(ex)
        srv.register("a", fps=1.0)
        with pytest.raises(ServeError, match="already registered"):
            srv.register("a", fps=1.0)

    def test_kill_guard_rails(self, fa_setup):
        ex, frames, base = fa_setup
        srv = _server(ex)
        with pytest.raises(ServeError, match="out of range"):
            srv.kill_device(99)
        with pytest.raises(ServeError, match="last healthy"):
            for i in range(len(srv._devices)):
                srv.kill_device(i)


# ---------------------------------------------------------------------------
# bounded queues + DRR fair shedding (tentpole b, satellite 3)
# ---------------------------------------------------------------------------


class TestFairShedding:
    def test_bounded_queue_sheds_oldest_and_surfaces(self, fa_setup):
        ex, frames, base = fa_setup
        srv = _server(ex, max_queue_frames=4)
        srv.register("a", fps=1.0)
        for i in range(7):
            srv.enqueue("a", frames[i % len(frames)], t=i * 0.1)
        rep = srv.tick(1.0)
        (shed,) = rep.shed
        assert shed.sid == "a"
        assert shed.seqs == (0, 1, 2)       # oldest first, never silent
        assert shed.arrivals == (0.0, pytest.approx(0.1),
                                 pytest.approx(0.2))
        audit = srv.seq_audit()
        assert audit["ok"] and audit["shed"] == 3
        assert audit["enqueued"] == 7
        # shed is reported exactly once
        assert srv.tick(2.0).shed == ()

    def test_shed_order_deterministic_across_runs(self, fa_setup):
        ex, frames, base = fa_setup

        def run():
            srv = _server(ex, max_queue_frames=2)
            out = []
            for sid in ("a", "b"):
                srv.register(sid, fps=1.0)
            for k in range(6):
                for sid in ("a", "b"):
                    srv.enqueue(sid, frames[k % len(frames)], t=float(k))
            rep = srv.tick(1.0)
            out.extend((s.sid, s.seqs) for s in rep.shed)
            return out, srv.seq_audit()

        (o1, a1), (o2, a2) = run(), run()
        assert o1 == o2
        assert o1 == [("a", (0, 1, 2, 3)), ("b", (0, 1, 2, 3))]
        assert a1 == a2 and a1["ok"]

    def test_drr_starvation_bound_under_sustained_overload(self, fa_setup):
        # 6 continuously-backlogged hot streams on one rung, capacity 2:
        # the documented bound says every stream is served at least once
        # every ceil(6/2) = 3 ticks — DRR makes it a perfect rotation
        ex, frames, base = fa_setup
        pair = _motion_pair(frames, base)
        sids = [f"s{k}" for k in range(6)]
        srv = _server(ex, chunk=2, capacity=2)
        for sid in sids:
            # declare low fps: admission is not the subject here — the
            # *actual* offered load below is ~3x the service capacity
            dec = srv.register(sid, fps=0.5)
            assert dec.admitted, dec
        served_at = {sid: [] for sid in sids}
        for tick in range(9):
            for sid in sids:
                if len(srv.streams[sid].queue) < 2:
                    srv.enqueue(sid, pair[0], t=float(tick))
                    srv.enqueue(sid, pair[1], t=float(tick) + 0.5)
            rep = srv.tick(float(tick + 1))
            assert rep.n_served == 2 and rep.n_requeued == 4
            for c in rep.completions:
                served_at[c.sid].append(tick)
        for sid in sids:
            ticks = served_at[sid]
            assert ticks, f"{sid} starved entirely"
            # first service within the bound, then every ceil(R/C) ticks
            assert ticks[0] <= 2, (sid, ticks)
            assert all(b - a == 3 for a, b in zip(ticks, ticks[1:])), \
                (sid, ticks)
        assert srv.seq_audit()["ok"]

    def test_uncontended_fleet_keeps_zero_deficits(self, fa_setup):
        # no contention -> DRR degenerates to registration order and
        # normalization keeps every credit at zero (the PR 8 scheduler)
        ex, frames, base = fa_setup
        pair = _motion_pair(frames, base)
        srv = _server(ex, chunk=2, capacity=4)
        for sid in ("a", "b", "c"):
            srv.register(sid, fps=1.0)
        for tick in range(3):
            for sid in ("a", "b", "c"):
                srv.enqueue(sid, pair[0], t=float(tick))
                srv.enqueue(sid, pair[1], t=float(tick))
            rep = srv.tick(float(tick + 1))
            assert [c.sid for c in rep.completions] == ["a", "b", "c"]
        assert all(st.deficit == 0.0 for st in srv.streams.values())


# ---------------------------------------------------------------------------
# zero-fault pin: an inert chaos plane changes nothing
# ---------------------------------------------------------------------------


class TestZeroFaultIdentity:
    def test_inert_spec_is_bit_identical_to_no_chaos(self, fa_setup):
        ex, frames, base = fa_setup

        def run(chaos):
            srv = _server(ex, chunk=2, capacity=2, chaos=chaos,
                          link=ETH_25G_LINK)
            dec = srv.register("a", fps=1.0, cut="vj", bits=8)
            assert dec.admitted and dec.cut == "vj", dec
            srv.register("b", fps=1.0)
            reports = []
            for tick in range(4):
                for sid in ("a", "b"):
                    srv.enqueue(sid, frames[(2 * tick) % 8], t=float(tick))
                    srv.enqueue(sid, frames[(2 * tick + 1) % 8],
                                t=float(tick) + 0.5)
                reports.append(srv.tick(float(tick + 1)))
            return reports

        plain = run(None)
        inert = run(ChaosSpec())            # no fault models: inert
        for rp, ri in zip(plain, inert):
            assert (rp.n_served, rp.n_quiet, rp.n_requeued) == \
                (ri.n_served, ri.n_quiet, ri.n_requeued)
            assert rp.bytes_sent == ri.bytes_sent
            assert ri.shed == () and ri.n_failed_tx == 0
            assert ri.ladder_moves == () and ri.device_events == ()
            for cp, ci in zip(rp.completions, ri.completions):
                assert cp.sid == ci.sid and cp.seqs == ci.seqs
                assert cp.wire_bytes == ci.wire_bytes
                for k, v in cp.result.items():
                    assert np.array_equal(np.asarray(v),
                                          np.asarray(ci.result[k])), k


# ---------------------------------------------------------------------------
# fault injection: retries charge bytes, failures requeue, ladders move
# ---------------------------------------------------------------------------


class TestChaosDelivery:
    def test_failed_tx_requeues_and_ladder_reaches_on_node(self, fa_setup):
        # a stream whose channel is perma-dead never delivers an offloaded
        # chunk: every exhausted delivery re-queues (no frame lost) and
        # walks the ladder down until the terminal all-on-node rung, where
        # frames finally complete locally
        ex, frames, base = fa_setup
        spec = ChaosSpec(loss=ALWAYS_LOST, max_retries=1, seed=3)
        srv = _server(ex, chunk=2, capacity=2, chaos=spec,
                      link=ETH_25G_LINK)
        dec = srv.register("a", fps=1.0, cut="vj", bits=8)
        assert dec.admitted and dec.cut == "vj", dec
        delivered = 0
        for tick in range(6):
            if len(srv.streams["a"].queue) < 2:
                srv.enqueue("a", frames[2 * (tick % 4)], t=float(tick))
                srv.enqueue("a", frames[2 * (tick % 4) + 1],
                            t=float(tick) + 0.5)
            rep = srv.tick(float(tick + 1))
            delivered += sum(c.n_frames for c in rep.completions)
            if rep.n_failed_tx:
                # retry bytes hit the uplink even though nothing delivered
                assert rep.bytes_sent > 0.0
        st = srv.streams["a"]
        assert st.tx_failures >= 2
        assert st.ladder.level == len(st.ladder.rungs) - 1
        assert tuple(st.ladder.rung) == ("on_node", None)
        assert st.rung == (None, None)      # placement went local
        assert delivered > 0                # ...and frames then completed
        assert srv.seq_audit()["ok"]

    def test_ladder_descends_then_recovers_with_hysteresis(self, fa_setup):
        ex, frames, base = fa_setup
        spec = ChaosSpec(loss=SOMETIMES_LOST, max_retries=1, seed=5,
                         ladder_recover_after=2)
        srv = _server(ex, chunk=2, capacity=2, chaos=spec,
                      link=ETH_25G_LINK)
        engine = srv._chaos
        dec = srv.register("a", fps=1.0, cut="vj", bits=8)
        assert dec.admitted and dec.cut == "vj", dec
        # script the channel: two exhausted deliveries (descend twice),
        # then clean first-attempt deliveries (recover with hysteresis)
        engine._injectors["a"] = _ScriptedInjector(
            ["lost", "lost", "lost", "lost"])
        levels = [0]
        for tick in range(8):
            if len(srv.streams["a"].queue) < 2:
                srv.enqueue("a", frames[2 * (tick % 4)], t=float(tick))
                srv.enqueue("a", frames[2 * (tick % 4) + 1],
                            t=float(tick) + 0.5)
            rep = srv.tick(float(tick + 1))
            for _sid, _old, new in rep.ladder_moves:
                levels.append(new)
        st = srv.streams["a"]
        # 0 -> 1 -> 2 (on_node) on the two failures, then two clean probe
        # deliveries per recovery step walk it back: 2 -> 1 -> 0
        assert levels[:3] == [0, 1, 2]
        assert st.ladder.level == 0, (levels, st.ladder.transitions)
        assert levels == [0, 1, 2, 1, 0]
        assert srv.seq_audit()["ok"]

    def test_retx_factor_inflates_admission(self, fa_setup):
        ex, frames, base = fa_setup
        spec = ChaosSpec(loss=SOMETIMES_LOST, max_retries=2, seed=1)
        clean = _server(ex, chunk=2, capacity=2, link=ETH_25G_LINK)
        srv = _server(ex, chunk=2, capacity=2, chaos=spec,
                      link=ETH_25G_LINK)
        d0 = clean.register("a", fps=1.0, cut="vj", bits=8)
        d1 = srv.register("a", fps=1.0, cut="vj", bits=8)
        factor = ChaosEngine(spec).retx_factor("a")
        assert factor > 1.0
        assert d1.predicted_bps == pytest.approx(
            d0.predicted_bps * factor)

    def test_fault_sequences_deterministic_per_sid(self):
        spec = ChaosSpec(loss=SOMETIMES_LOST, seed=11,
                         corrupt_fraction=0.2)
        a = ChaosEngine(spec).injector_for("cam-7")
        b = ChaosEngine(spec).injector_for("cam-7")
        c = ChaosEngine(spec).injector_for("cam-8")
        sa = [a.attempt(t * 0.1) for t in range(64)]
        sb = [b.attempt(t * 0.1) for t in range(64)]
        sc = [c.attempt(t * 0.1) for t in range(64)]
        assert sa == sb                     # same sid: same fault process
        assert sa != sc                     # different sid: independent

    def test_faulty_fraction_selects_deterministically(self):
        spec = ChaosSpec(loss=SOMETIMES_LOST, faulty_fraction=0.5, seed=2)
        eng = ChaosEngine(spec)
        picks = {f"cam-{k}": eng.is_faulty(f"cam-{k}") for k in range(64)}
        assert 10 < sum(picks.values()) < 54    # a real split
        eng2 = ChaosEngine(spec)
        assert picks == {s: eng2.is_faulty(s) for s in picks}


# ---------------------------------------------------------------------------
# device-kill failover (tentpole a, satellite 3) — fake multi-device host
# ---------------------------------------------------------------------------


class TestDeviceFailover:
    def test_kill_resharding_is_bit_identical(self, subproc):
        out = subproc("""
            import numpy as np
            import jax, jax.numpy as jnp
            from benchmarks.workloads import fa_cascade, fa_scan
            from repro.camera.face_nn import train_face_nn
            from repro.camera.pipelines import FaceAuthExecutor
            from repro.camera.synthetic import face_dataset, security_video
            from repro.camera.serve import ChaosSpec, ServeConfig, \\
                StreamingServer

            assert jax.local_device_count() == 8
            frames, _ = security_video(n_frames=10, motion_frames=5, seed=1)
            casc = fa_cascade(smoke=True)
            X, y, _ = face_dataset(n_per_class=80, seed=3)
            nn = train_face_nn(X, y, steps=60)
            sf, st, ad = fa_scan(True)
            ex = FaceAuthExecutor(casc, nn, frames.shape[1],
                                  frames.shape[2], scale_factor=sf,
                                  step=st, adaptive=ad)
            ex.calibrate(frames)
            assert ex.stream_parallel          # pmap path is live

            def run(spec, ticks):
                cfg = ServeConfig(chunk=2, capacity=8, tick_s=1.0,
                                  max_queue_s=100.0)
                srv = StreamingServer(ex, config=cfg, chaos=spec)
                for k in range(8):
                    dec = srv.register(f"s{k}", fps=0.5)
                    assert dec.admitted, dec
                srv.prewarm([(None, None)], device_counts=(4,))
                reps = []
                for tick in range(ticks):
                    for k in range(8):
                        srv.enqueue(f"s{k}", frames[2 * (tick % 4)],
                                    t=float(tick))
                        srv.enqueue(f"s{k}", frames[2 * (tick % 4) + 1],
                                    t=float(tick) + 0.5)
                    reps.append(srv.tick(float(tick + 1)))
                return srv, reps

            # healthy 8-device run vs a run whose chaos schedule kills the
            # last four devices before tick 2 (8 streams re-shard onto a
            # 4-device pmap), then restores them
            healthy, hr = run(None, 4)
            spec = ChaosSpec(device_events=((1, "kill", 7), (1, "kill", 6),
                                            (1, "kill", 5), (1, "kill", 4),
                                            (3, "restore", 7),
                                            (3, "restore", 6),
                                            (3, "restore", 5),
                                            (3, "restore", 4)))
            degraded, dr = run(spec, 4)
            assert dr[1].device_events == (("kill", 7), ("kill", 6),
                                           ("kill", 5), ("kill", 4))
            assert dr[3].device_events[0][0] == "restore"
            for rh, rd in zip(hr, dr):
                assert rh.n_served == rd.n_served
                assert rh.n_quiet == rd.n_quiet
                for ch, cd in zip(rh.completions, rd.completions):
                    assert ch.sid == cd.sid and ch.seqs == cd.seqs
                    for k, v in ch.result.items():
                        assert np.array_equal(np.asarray(v),
                                              np.asarray(cd.result[k])), k
            # the degraded ticks really used the survivor pmap closure
            keys = set(degraded._group_steps)
            assert ((None, None), None) in keys
            assert any(k[1] is not None and len(k[1]) == 4 for k in keys)
            assert degraded.seq_audit()["ok"]
            print("FAILOVER_OK")
        """)
        assert "FAILOVER_OK" in out


# ---------------------------------------------------------------------------
# checkpoint / restore: brownout-restartable server (tentpole d)
# ---------------------------------------------------------------------------


class TestCheckpointRestore:
    def test_roundtrip_resumes_bit_identical(self, fa_setup, tmp_path):
        ex, frames, base = fa_setup
        pair = _motion_pair(frames, base)

        def feed(srv, tick):
            for sid in ("a", "b", "c"):
                if sid in srv.streams and not srv.streams[sid].draining:
                    srv.enqueue(sid, pair[0], t=float(tick))
                    srv.enqueue(sid, pair[1], t=float(tick) + 0.5)

        srv = _server(ex, chunk=2, capacity=2, max_queue_frames=4,
                      link=ETH_25G_LINK)
        dec = srv.register("a", fps=1.0, cut="vj", bits=8)
        assert dec.admitted and dec.cut == "vj", dec
        srv.register("b", fps=1.0)
        srv.register("c", fps=1.0)
        for tick in range(3):
            feed(srv, tick)
            srv.tick(float(tick + 1))
        srv.enqueue("c", pair[0], t=3.0)    # mid-drain state survives
        srv.unregister("c")

        path = srv.checkpoint(str(tmp_path))
        assert path.endswith(f"step_{srv.tick_count:08d}")
        audit0 = srv.seq_audit()
        rest = StreamingServer.restore(str(tmp_path), ex,
                                       config=srv.cfg)
        assert rest.seq_audit() == audit0
        assert rest.tick_count == srv.tick_count
        assert set(rest.streams) == set(srv.streams)
        assert rest.streams["c"].draining
        for sid, st in srv.streams.items():
            rs = rest.streams[sid]
            assert (rs.seq_next, rs.delivered_n, rs.last_served_seq,
                    rs.shed_n, rs.deficit, rs.order) == \
                (st.seq_next, st.delivered_n, st.last_served_seq,
                 st.shed_n, st.deficit, st.order)
            assert [e[2] for e in rs.queue] == [e[2] for e in st.queue]

        # both servers continue identically: no frame lost, none re-served
        for tick in range(3, 6):
            feed(srv, tick)
            feed(rest, tick)
            ro, rr = srv.tick(float(tick + 1)), rest.tick(float(tick + 1))
            assert [(c.sid, c.seqs, c.kind) for c in ro.completions] == \
                [(c.sid, c.seqs, c.kind) for c in rr.completions]
            for co, cr in zip(ro.completions, rr.completions):
                for k, v in co.result.items():
                    assert np.array_equal(np.asarray(v),
                                          np.asarray(cr.result[k])), k
        assert srv.seq_audit() == rest.seq_audit()
        assert rest.seq_audit()["ok"]

    def test_restore_preserves_ladder_and_chaos_state(self, fa_setup,
                                                      tmp_path):
        ex, frames, base = fa_setup
        spec = ChaosSpec(loss=ALWAYS_LOST, max_retries=0, seed=9)
        srv = _server(ex, chunk=2, capacity=2, chaos=spec,
                      link=ETH_25G_LINK)
        dec = srv.register("a", fps=1.0, cut="vj", bits=8)
        assert dec.admitted and dec.cut == "vj", dec
        for tick in range(3):
            if len(srv.streams["a"].queue) < 2:
                srv.enqueue("a", frames[0], t=float(tick))
                srv.enqueue("a", frames[1], t=float(tick) + 0.5)
            srv.tick(float(tick + 1))
        lvl = srv.streams["a"].ladder.level
        assert lvl > 0                       # the incident is in flight
        srv.checkpoint(str(tmp_path))
        rest = StreamingServer.restore(str(tmp_path), ex, config=srv.cfg,
                                       chaos=spec)
        rst = rest.streams["a"]
        assert rst.ladder.level == lvl
        assert rst.ladder.rungs == srv.streams["a"].ladder.rungs
        assert rst.ladder.transitions == srv.streams["a"].ladder.transitions
        assert rest.seq_audit() == srv.seq_audit()
        assert rest.seq_audit()["ok"]

    def test_restore_errors_are_named(self, fa_setup, tmp_path):
        ex, frames, base = fa_setup
        with pytest.raises(ServeError, match="no complete checkpoint"):
            StreamingServer.restore(str(tmp_path), ex)
        # a foreign checkpoint (wrong schema) is refused, not misread
        from repro.ckpt.checkpoint import save_checkpoint
        save_checkpoint(str(tmp_path), 0, {"w": np.zeros(3)},
                        extra={"version": 99})
        with pytest.raises(ServeError, match="version"):
            StreamingServer.restore(str(tmp_path), ex)
