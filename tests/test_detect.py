"""Fused detection front-end vs golden oracle + compaction semantics.

Golden-equivalence policy: the fused gather path and the per-window
reference compute the same math from differently-associated f32 sums
(frame-level vs per-window integral image), so a window whose stump
response lands within fp noise of a trained threshold can legitimately
flip.  The equivalence tests therefore demand *identical* detection sets
except for windows that are provably fp-borderline (some stump margin
below 1e-4 of the normalized response), and that those are rare.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.camera.integral import integral_image
from repro.camera.synthetic import face_dataset, security_video
from repro.camera.viola_jones import (
    BASE,
    CORNER_SLOTS,
    FusedDetector,
    _haar_response,
    build_gather_tables,
    build_scan_grid,
    detect_faces,
    detect_faces_batch,
    eval_features,
    eval_features_scaled,
    feature_corners,
    make_feature_pool,
    scale_feature,
    train_cascade,
)
from repro.core.cascade import capacities_from_counts, compaction_work

SCAN = dict(scale_factor=1.4, step=4.0, adaptive=False)   # coarse: fast oracle


@pytest.fixture(scope="module")
def cascade():
    X, y, _ = face_dataset(n_per_class=250, seed=0)
    pool = make_feature_pool(n=200)
    return train_cascade(X, y, pool, n_stages=6, per_stage=20, seed=0)


@pytest.fixture(scope="module")
def video():
    frames, truth = security_video(n_frames=6, motion_frames=4, seed=1)
    return frames, truth


class TestFeatureGeometry:
    def test_scale_identity_at_base(self):
        for f in make_feature_pool(n=60):
            assert scale_feature(f, BASE) == f

    def test_scaled_features_stay_inside_and_divisible(self):
        for f in make_feature_pool(n=60, seed=2):
            for win in (20, 25, 31, 49, 95, 119):
                g = scale_feature(f, win)
                assert 0 <= g.y and g.y + g.h <= win
                assert 0 <= g.x and g.x + g.w <= win
                split = g.w if g.kind in (0, 2) else g.h
                assert split % (2 if g.kind < 2 else 3) == 0

    def test_corner_decomposition_matches_rect_sums(self):
        """<= 8 corner taps reproduce the 2-/3-rect window-sum arithmetic."""
        rng = np.random.default_rng(0)
        for win in (BASE, 31):
            patches = jnp.asarray(rng.random((4, win, win), np.float32))
            ii = integral_image(patches)                  # (4, win+1, win+1)
            iif = np.asarray(ii).reshape(4, -1)
            stride = win + 1
            for f in make_feature_pool(n=40, seed=3):
                g = scale_feature(f, win)
                want = np.asarray(_haar_response(ii, g))
                taps = feature_corners(g)
                assert len(taps) <= CORNER_SLOTS
                got = sum(wv * iif[:, dy * stride + dx] for dy, dx, wv in taps)
                np.testing.assert_allclose(got, want, atol=2e-3)

    def test_eval_features_scaled_identity_at_base(self):
        rng = np.random.default_rng(1)
        wins = jnp.asarray(rng.random((16, BASE, BASE), np.float32))
        feats = make_feature_pool(n=30, seed=4)
        a = eval_features(wins, feats)
        b = eval_features_scaled(wins, BASE, feats)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _borderline(cascade, frame, pos, tol=1e-4):
    """True if the window's cascade decision is fp-ambiguous: some stump
    response or stage score within ``tol`` of its threshold."""
    y, x, win = pos
    patch = jnp.asarray(frame[y:y + win, x:x + win][None])
    F = np.asarray(eval_features_scaled(patch, win, cascade.feats))[0]
    if np.min(np.abs(F - cascade.thresholds)) < tol:
        return True
    pred = cascade.polarity * np.sign(F - cascade.thresholds)
    pred[pred == 0] = 1.0
    weighted = cascade.alphas * pred
    off = 0
    for si, size in enumerate(cascade.stage_sizes):
        score = weighted[off:off + size].sum()
        if abs(score - cascade.stage_thresholds[si]) < tol:
            return True
        if score < cascade.stage_thresholds[si]:
            break
        off += size
    return False


class TestGoldenEquivalence:
    def test_fused_matches_reference_detections(self, cascade, video):
        frames, _ = video
        det = FusedDetector(cascade, frames.shape[1], frames.shape[2], **SCAN)
        det.calibrate(frames[:2])
        dets, stats = det.detect(frames)
        assert stats["dropped"] == 0
        n_diff = 0
        for i in range(len(frames)):
            ref, n_inv, _ = detect_faces(cascade, frames[i], SCAN["scale_factor"],
                                         SCAN["step"], SCAN["adaptive"])
            assert n_inv == stats["n_windows"]
            diff = set(ref) ^ set(dets[i])
            for pos in diff:
                assert _borderline(cascade, frames[i], pos), (
                    f"frame {i}: non-borderline mismatch at {pos}")
            n_diff += len(diff)
        assert n_diff <= 2   # borderline flips must stay rare

    def test_detect_faces_batch_convenience(self, cascade, video):
        frames, _ = video
        dets, stats = detect_faces_batch(cascade, frames[:3], **{
            "scale_factor": SCAN["scale_factor"], "step": SCAN["step"],
            "adaptive": SCAN["adaptive"]})
        assert len(dets) == 3
        assert stats["dropped"] == 0
        # cached detector: second call must not rebuild (same object results)
        dets2, _ = detect_faces_batch(cascade, frames[:3], **{
            "scale_factor": SCAN["scale_factor"], "step": SCAN["step"],
            "adaptive": SCAN["adaptive"]})
        assert dets == dets2


class TestCompaction:
    def test_compacting_matches_masked_at_ample_capacity(self, cascade, video):
        """compacting_cascade with generous capacities == the masked oracle
        (full-capacity pass), on the real detector stages."""
        frames, _ = video
        h, w = frames.shape[1:]
        masked = FusedDetector(cascade, h, w, **SCAN)          # full caps
        n = masked.n_windows
        caps = [n] + [max(512, n // 8)] * (masked.n_stages - 1)
        compacted = FusedDetector(cascade, h, w, capacities=caps, **SCAN)
        m_mask, m_surv, m_drop = (np.asarray(a) for a in masked(frames[:3]))
        c_mask, c_surv, c_drop = (np.asarray(a) for a in compacted(frames[:3]))
        assert int(c_drop.sum()) == 0
        np.testing.assert_array_equal(m_mask, c_mask)
        np.testing.assert_array_equal(m_surv, c_surv)

    def test_capacity_overflow_drops_are_counted(self, cascade, video):
        frames, _ = video
        h, w = frames.shape[1:]
        masked = FusedDetector(cascade, h, w, **SCAN)
        _, surv, _ = (np.asarray(a) for a in masked(frames[:2]))
        if surv[:, 0].max() < 2:
            pytest.skip("stage 0 rejects everything on this workload")
        tight = [masked.n_windows] + [1] * (masked.n_stages - 1)
        det = FusedDetector(cascade, h, w, capacities=tight, **SCAN)
        mask, _, dropped = (np.asarray(a) for a in det(frames[:2]))
        assert int(dropped.sum()) > 0
        assert mask.sum() <= surv[:, -1].sum()

    def test_calibrated_capacities_cover_workload(self, cascade, video):
        frames, _ = video
        det = FusedDetector(cascade, frames.shape[1], frames.shape[2], **SCAN)
        caps = det.calibrate(frames[:2])
        assert caps[0] == det.n_windows
        assert all(c <= det.n_windows for c in caps)
        _, _, dropped = det(frames)
        assert int(np.asarray(dropped).sum()) == 0

    def test_capacities_from_counts_helper(self):
        caps = capacities_from_counts(10000, [900, 40, 7], margin=1.5,
                                      quantum=128)
        assert caps[0] == 10000
        assert caps[1] >= int(900 * 1.5) and caps[1] % 128 == 0
        assert caps[2] >= 128
        masked, compacted = compaction_work([330, 330, 330], 10000, caps)
        assert compacted < masked


class TestSyntheticRegression:
    def test_security_video_clamps_motion_frames(self):
        frames, truth = security_video(n_frames=3, motion_frames=12, seed=0)
        assert len(frames) == 3
        assert sum(t["moving"] for t in truth) <= 2

    def test_feature_pool_splits_divisible(self):
        for f in make_feature_pool(n=120, seed=7):
            split = f.w if f.kind in (0, 2) else f.h
            assert split % (2 if f.kind < 2 else 3) == 0
