"""Roofline table from the dry-run artifacts (assignment deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), builds
the three-term roofline per (arch x shape x mesh) cell, identifies the
dominant bottleneck, and ranks cells for hillclimbing:
  worst roofline fraction | most collective-bound | most paper-representative.
"""

from __future__ import annotations

import glob
import json
import os

from repro.core.costmodel import Roofline, format_roofline_table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def _memory_bytes(r) -> float:
    """Global HBM-byte estimate for one cell.

    ``hlo_bytes`` (the instruction-level proxy) counts every intermediate
    as an HBM round-trip — a gross upper bound for scan-lowered models
    where XLA fuses the loop body.  XLA's own ``bytes accessed`` models
    fusion but counts loop bodies once; we loop-correct it with the
    measured flop ratio (loop-aware flops / single-visit flops), which is
    exact when loop iterations are homogeneous (they are: layer periods
    and kv chunks).  Both numbers are recorded; this is the headline term.
    """
    xla = r.get("xla_cost_analysis", {})
    xla_bytes = xla.get("bytes_per_device", 0.0)
    xla_flops = xla.get("flops_per_device", 0.0)
    if xla_bytes and xla_flops:
        ratio = max(1.0, (r["hlo_flops"] / r["chips"]) / xla_flops)
        return xla_bytes * ratio * r["chips"]
    return r["hlo_bytes"]


def _ideal_bytes(r) -> float:
    """Structural minimum global HBM traffic for one cell.

    train:   3 param reads (fwd/remat/bwd, bf16) + f32 grad write + opt
             read/write (12 B/param x2) + saved boundary activations x2
    prefill: 1 param read + cache write + activation stream
    decode:  1 param read + 1 cache read/write per token step
    """
    from repro.configs.registry import CONFIGS
    from repro.configs.shapes import SHAPES
    from repro.models.transformer import Model
    import jax

    cfg = CONFIGS[r["arch"]]
    shape = SHAPES[r["shape"]]
    model = Model(cfg)
    n = r["n_params"]
    tokens = shape.batch * shape.seq
    act = tokens * cfg.d_model * 2 * cfg.n_layers   # bf16 boundary activations
    if shape.mode == "train":
        return 3 * 2 * n + 4 * n + 2 * 12 * n + 2 * act
    cache_shapes = jax.eval_shape(lambda: model.init_cache(shape.batch, shape.seq))
    cache = sum(s.size * s.dtype.itemsize
                for s in jax.tree_util.tree_leaves(cache_shapes))
    if shape.mode == "prefill":
        return 2 * n + cache + 2 * act
    return 2 * n + 2 * cache               # decode: params + cache r/w


def load_cells(dryrun_dir: str = DRYRUN_DIR, mesh: str = "16x16"):
    cells = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            cells.append((r, None))
            continue
        rl = Roofline(
            name=f"{r['arch']}|{r['shape']}",
            flops=r["hlo_flops"],
            hbm_bytes=_memory_bytes(r),
            collective_bytes=r["collective_bytes"],
            n_chips=r["chips"],
            model_flops=r["model_flops"],
            ideal_bytes=_ideal_bytes(r),
        )
        cells.append((r, rl))
    return cells


def summarize(mesh: str = "16x16"):
    cells = load_cells(mesh=mesh)
    rows = [rl for _, rl in cells if rl is not None]
    print(format_roofline_table(rows))
    print()
    for r, rl in cells:
        if rl is None:
            print(f"{r['cell']:<44s} SKIPPED: {r.get('reason', r.get('error', ''))[:70]}")
    ok = [(r, rl) for r, rl in cells if rl is not None]
    if not ok:
        return
    worst = min(ok, key=lambda x: x[1].roofline_fraction)
    coll = max(ok, key=lambda x: x[1].collective_s / max(x[1].step_s, 1e-12))
    print()
    print(f"hillclimb candidates ({mesh}):")
    print(f"  worst roofline fraction : {worst[1].name} "
          f"({100*worst[1].roofline_fraction:.2f}%)")
    print(f"  most collective-bound   : {coll[1].name} "
          f"(coll {coll[1].collective_s:.3f}s vs step {coll[1].step_s:.3f}s)")
    print("  paper-representative    : deepseek-v2-236b|train_4k "
          "(EP expert placement + grad compression = the comp-comm cut)")


def main(smoke: bool = False):
    meshes = ("16x16",) if smoke else ("16x16", "2x16x16")
    for mesh in meshes:
        print(f"==== mesh {mesh} (baseline plans) ====")
        summarize(mesh)
        print()
    if smoke:
        return

    hc_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "hillclimb")
    if os.path.isdir(hc_dir):
        print("==== hillclimbed cells (§Perf; compare against baseline rows) ====")
        for mesh in ("16x16", "2x16x16"):
            rows = [rl for _, rl in load_cells(hc_dir, mesh) if rl is not None]
            if rows:
                print(f"-- {mesh} --")
                print(format_roofline_table(rows))
    gc_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "hillclimb_gc")
    if os.path.isdir(gc_dir):
        print("-- with int8+EF pod-axis gradient compression --")
        rows = [rl for _, rl in load_cells(gc_dir, "2x16x16") if rl is not None]
        if rows:
            print(format_roofline_table(rows))


if __name__ == "__main__":
    main()
