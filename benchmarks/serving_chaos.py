"""Chaos-hardened serving fleet benchmark (DESIGN.md §14 acceptance).

Sweeps the §14 fault grid — Gilbert–Elliott stream loss x device-kill
schedule x client brownout — over a WISPCam fleet on one
:class:`StreamingServer` and reports, per cell:

* **exactly-once accounting**: every assigned frame seq ends the run
  delivered once, shed once (surfaced in a ``TickReport``), or still
  queued — never lost, never double-served (``seq_audit`` + an
  independent harness-side partition check);
* **fair shedding**: the maximum DRR service gap of any
  continuously-backlogged stream against the documented bound
  ``ceil(R / capacity) + ladder_depth`` ticks;
* **recovery**: p99 micro-batch dispatch latency measured only *after*
  the last fault clears (device restored, brownout over) against the
  serving SLO;
* the **zero-fault pin**: a run under an inert ``ChaosSpec`` is compared
  leaf-for-leaf to the same drive with no chaos plane at all — the PR 8
  serving path — and must be bit-identical;
* the **§15 telemetry plane**: the zero-fault cell re-driven with
  ``repro.obs.Telemetry`` attached (p99 overhead must stay under 5% at
  acceptance scale), plus a loss+kill drive whose exported JSONL alone
  must prove the kill chain — injected device-kill -> same-tick failover
  re-shard -> ladder descent -> device restore -> serving again — and
  whose Perfetto export loads as well-formed ``trace_event`` JSON.

The worst cell (loss + kill + brownout) additionally browns out the
*server* mid-drive: the fleet checkpoints at a tick boundary, the server
object is discarded, and a ``StreamingServer.restore`` resumes the drive
— the accounting identity must hold across the restart.

The sweep itself runs in a child process with 8 fake CPU devices (the
pmapped local placement group needs a multi-device host to lose one), via
``benchmarks.timing.run_json_child``; ``--smoke`` drives a toy fleet over
a reduced grid, the full run puts 1024 streams through the acceptance
cells.
"""

from __future__ import annotations

import json
import math
import sys
import tempfile

import numpy as np

_SMOKE_TIMEOUT = 540
_FULL_TIMEOUT = 3000


# ---------------------------------------------------------------------------
# child: the actual sweep (runs under --xla_force_host_platform_device_count)
# ---------------------------------------------------------------------------


def _specs(mode: str):
    """The fault grid: (label, loss?, kill?, brownout?) cells."""
    full = mode == "full"
    grid = []
    if full:
        for lo in (False, True):
            for ki in (False, True):
                for br in (False, True):
                    grid.append((f"loss{int(lo)}_kill{int(ki)}"
                                 f"_brown{int(br)}", lo, ki, br))
    else:
        grid = [("loss0_kill0_brown0", False, False, False),
                ("loss1_kill0_brown0", True, False, False),
                ("loss0_kill1_brown0", False, True, False),
                ("loss0_kill0_brown1", False, False, True),
                ("loss1_kill1_brown1", True, True, True)]
    return grid


def _make_spec(lo, ki, br, *, ticks, smoke, seed=0):
    from repro.camera.offload.link import BrownoutModel, GilbertElliott
    from repro.camera.serve import ChaosSpec

    if smoke:
        # smoke drives few chunks, so the channel must misbehave fast:
        # ~50% stationary loss and a single retry surfaces failed_tx and
        # ladder descent within the short drive
        loss = GilbertElliott(p_gb=0.3, p_bg=0.3, loss_bad=0.9,
                              loss_good=0.1) if lo else None
    else:
        # acceptance channel, <= 10% stationary:
        # pi_bad = .05/.50 = 0.1, loss = 0.1 * 0.9 = 0.09
        loss = GilbertElliott(p_gb=0.05, p_bg=0.45, loss_bad=0.9,
                              loss_good=0.0) if lo else None
    # one brownout window inside the drive (smoke: ~6 s on / ~3 s dark;
    # full: ~6 s on / ~6 s dark so the window clears before recovery is
    # measured)
    if br:
        brown = (BrownoutModel(harvest_w=1e-3, storage_j=3e-3,
                               load_w=1.5e-3) if smoke else
                 BrownoutModel(harvest_w=1e-3, storage_j=6e-3,
                               load_w=2e-3))
    else:
        brown = None
    kill_t, back_t = max(1, ticks // 4), max(2, ticks // 2)
    events = (((kill_t, "kill", 7), (kill_t, "kill", 6),
               (kill_t, "kill", 5), (kill_t, "kill", 4),
               (back_t, "restore", 7), (back_t, "restore", 6),
               (back_t, "restore", 5), (back_t, "restore", 4))
              if ki else ())
    if loss is None and brown is None and not events:
        return None, 0
    spec = ChaosSpec(loss=loss, brownout=brown, device_events=events,
                     max_retries=1 if smoke else 2, seed=seed,
                     ladder_window=4 if smoke else 8,
                     ladder_recover_after=4)
    # the tick after which the fleet counts as recovered: the last
    # scheduled fault clears (device restore; brownout recharge), plus
    # the ladder hysteresis window when a loss process kept ladders
    # active (full mode only — the smoke drive is too short to wait it
    # out and only asserts liveness)
    recover_at = back_t + 1 if ki else 0
    if br and not smoke:
        dark_end = (brown.storage_j / (brown.load_w - brown.harvest_w)
                    + brown.storage_j / brown.harvest_w)
        recover_at = max(recover_at, int(math.ceil(dark_end)) + 1)
    if lo and not smoke and recover_at:
        recover_at += spec.ladder_recover_after + 1
    if br and smoke:
        recover_at = max(recover_at, back_t + 1)
    return spec, recover_at


class _CellHarness:
    """Feeds one fleet, logs seqs, and tracks backlog service gaps."""

    def __init__(self, srv, specs, engine):
        self.srv = srv
        self.specs = specs        # sid -> (video, offset, frames_per_tick)
        self.engine = engine
        self.delivered: dict = {sid: [] for sid in specs}
        self.shed: dict = {sid: [] for sid in specs}
        self.gap: dict = {sid: 0 for sid in specs}
        self.max_gap = 0
        self.max_backlogged = 0
        self.events = []
        self.ladder_moves = 0
        self.failed_tx = 0
        self.t = 0.0

    def drive(self, ticks):
        srv, cfg = self.srv, self.srv.cfg
        for _ in range(ticks):
            live = srv.streams
            for sid, (video, off, n) in self.specs.items():
                st = live.get(sid)
                if st is None:
                    continue
                if self.engine is not None and \
                        not self.engine.node_powered(sid, self.t):
                    continue          # dark camera: nothing was captured
                for j in range(n):
                    idx = (off + st.seq_next) % len(video)
                    srv.enqueue(sid, video[idx], t=self.t + j / max(n, 1))
            backlogged = [sid for sid, st in srv.streams.items()
                          if len(st.queue) >= cfg.chunk]
            self.max_backlogged = max(self.max_backlogged, len(backlogged))
            self.t += cfg.tick_s
            rep = srv.tick(self.t)
            got = set()
            for c in rep.completions:
                self.delivered[c.sid].extend(c.seqs)
                got.add(c.sid)
            for s in rep.shed:
                self.shed[s.sid].extend(s.seqs)
            for sid in backlogged:
                if sid in got:
                    self.gap[sid] = 0
                else:
                    self.gap[sid] += 1
                    self.max_gap = max(self.max_gap, self.gap[sid])
            for sid in list(self.gap):
                if sid not in backlogged and sid not in got:
                    self.gap[sid] = 0
            self.events.extend(rep.device_events)
            self.ladder_moves += len(rep.ladder_moves)
            self.failed_tx += rep.n_failed_tx

    def adopt(self, srv):
        """Point the harness at a restored server (server brownout)."""
        self.srv = srv

    def exactly_once(self):
        """Harness-side partition proof, independent of ``seq_audit``:
        delivered + shed + still-queued seqs tile ``range(seq_next)``
        exactly, per stream — across restarts, because the logs span the
        whole drive while the counters live in the checkpoint."""
        for sid in self.specs:
            st = self.srv.streams.get(sid)
            if st is None:
                return False
            queued = [e[2] for e in st.queue]
            seen = self.delivered[sid] + self.shed[sid] + queued
            if len(seen) != st.seq_next:
                return False
            if sorted(seen) != list(range(st.seq_next)):
                return False
        return True


def _build_fleet(ex, ctl, link, cfg, pools, spec, *, n_local, n_off,
                 off_feed, shared_steps, shared_execs, prewarm_kill,
                 telemetry=None):
    from repro.camera.serve import StreamingServer

    quiet, hot = pools
    srv = StreamingServer(ex, link=link, controller=ctl, config=cfg,
                          chaos=spec, telemetry=telemetry)
    srv._group_steps = shared_steps       # reuse compiled placement groups
    srv._offload_execs = shared_execs     # across cells (same cfg/devices)
    specs = {}
    for k in range(n_local):
        sid = f"l{k}"
        dec = srv.register(sid, fps=0.5, motion_frac=0.1)
        assert dec.admitted, dec
        vid = quiet[k % len(quiet)]
        specs[sid] = (vid, (k * 7) % len(vid), 1)
    for k in range(n_off):
        sid = f"o{k}"
        hot_one = k % 8 == 7              # 1-in-8 motion-heavy streams
        vid = (hot[k % len(hot)] if hot_one else quiet[k % len(quiet)])
        dec = srv.register(sid, fps=1.0, cut="vj", bits=8,
                           motion_frac=0.3 if hot_one else 0.1)
        assert dec.admitted, dec
        specs[sid] = (vid, (k * 5) % len(vid), off_feed)
    # every group step a tick can reach must be compiled ahead of the
    # measured drive (the §13 contract): the granted rung, every ladder
    # rung below it (including the controller's cheapest-bytes retreat
    # cut), every cut a windowed re-solve can grant, and the local
    # group.  The big-model shape is capacity-static, so one bucket per
    # rung suffices; buckets only size the eager scorer stack.
    from repro.camera.serve import FA_CUTS

    rungs = [(None, None)] + [(c, b) for c in FA_CUTS for b in (8, 4)]
    srv.prewarm(rungs, max_ready=n_local + n_off + cfg.capacity,
                device_counts=(4,) if prewarm_kill else ())
    return srv, specs


def _run_cell(label, lo, ki, br, env, *, n_local, n_off, ticks,
              off_feed=1, smoke=True, server_brownout=False,
              telemetry=None):
    from repro.camera.serve import ChaosEngine, StreamingServer

    ex, ctl, link, cfg, pools, shared_steps, shared_execs = env
    spec, recover_at = _make_spec(lo, ki, br, ticks=ticks, smoke=smoke)
    srv, specs = _build_fleet(
        ex, ctl, link, cfg, pools, spec, n_local=n_local, n_off=n_off,
        off_feed=off_feed, shared_steps=shared_steps,
        shared_execs=shared_execs, prewarm_kill=ki, telemetry=telemetry)
    engine = srv._chaos
    h = _CellHarness(srv, specs, engine)

    restored_exact = None
    lat_prefix = []
    if server_brownout:
        # first half, then the server browns out: checkpoint at the tick
        # boundary, drop the object, restore, finish the drive
        half = ticks // 2
        h.drive(half)
        with tempfile.TemporaryDirectory() as td:
            srv.checkpoint(td)
            audit_before = srv.seq_audit()
            lat_prefix = list(srv.batch_lat_s)
            del srv
            srv = StreamingServer.restore(td, ex, link=link,
                                          controller=ctl, config=cfg,
                                          chaos=spec)
            srv._group_steps = shared_steps
            srv._offload_execs = shared_execs
            restored_exact = srv.seq_audit() == audit_before
            h.adopt(srv)
            h.drive(ticks - half)
    else:
        h.drive(ticks)

    # recovery: measure p99 only after the last scheduled fault clears
    post = [s for s in srv.batch_lat_s]
    if recover_at and len(srv.batch_lat_s) > 2:
        post = srv.batch_lat_s[-max(ticks - recover_at, 2):]
    post_p99 = float(np.quantile(np.asarray(post), 0.99)) if post else 0.0

    audit = srv.seq_audit()
    ladder_depth = 4                      # (vj,8)->(vj,4)->cheapest->on_node
    bound = (math.ceil(h.max_backlogged / max(cfg.capacity, 1))
             + ladder_depth)
    return {
        "label": label, "n_streams": len(srv.streams), "ticks": ticks,
        "delivered": audit["delivered"], "shed": audit["shed"],
        "queued": audit["queued"], "enqueued": audit["enqueued"],
        "audit_ok": bool(audit["ok"]), "exactly_once": h.exactly_once(),
        "failed_tx": h.failed_tx, "ladder_moves": h.ladder_moves,
        "device_events": len(h.events),
        "kill_fired": sum(1 for k, _ in h.events if k == "kill"),
        "p99_batch_s": srv.p99_batch_s(), "post_recovery_p99_s": post_p99,
        "slo_s": cfg.slo_s, "post_recovery_slo_ok": post_p99 <= cfg.slo_s,
        "max_gap_ticks": h.max_gap, "gap_bound_ticks": bound,
        "gap_ok": h.max_gap <= bound,
        "max_backlogged": h.max_backlogged,
        "restored_exact": restored_exact,
        "lat_s": [round(x, 3) for x in lat_prefix + list(srv.batch_lat_s)],
        "recover_at": recover_at,
        "retx_factor": (ChaosEngine(spec).retx_factor("o0")
                        if spec is not None else 1.0),
    }


def kill_chain(records):
    """Verify a device-kill is traceable end-to-end from trace records
    alone (the §15 acceptance): the injected ``chaos/device_kill`` event,
    a ``failover`` re-shard at the SAME tick, a ``ladder`` descent at or
    after it, the scheduled ``chaos/device_restore``, and a post-restore
    ``tick`` that served work again.  Returns a dict of the correlated
    ticks plus ``ok``; works on TraceRecord objects or their JSONL dicts
    (so the proof never needs the live server).
    """
    def _get(r, k, default=None):
        if isinstance(r, dict):
            return r.get(k, r.get("args", {}).get(k, default))
        return getattr(r, k, None) if k in ("kind", "name", "tick") \
            else r.args.get(k, default)

    kills = [r for r in records if _get(r, "kind") == "chaos"
             and _get(r, "name") == "device_kill"]
    if not kills:
        return {"ok": False, "why": "no device_kill in trace"}
    kill_tick = min(_get(r, "tick") for r in kills)
    failovers = [r for r in records if _get(r, "kind") == "failover"
                 and _get(r, "tick") == kill_tick]
    descents = [r for r in records if _get(r, "kind") == "ladder"
                and _get(r, "name") == "descend"
                and _get(r, "tick") >= kill_tick]
    restores = [r for r in records if _get(r, "kind") == "chaos"
                and _get(r, "name") == "device_restore"]
    restore_tick = min((_get(r, "tick") for r in restores), default=None)
    recovered = [r for r in records if _get(r, "kind") == "tick"
                 and restore_tick is not None
                 and _get(r, "tick") > restore_tick
                 and int(_get(r, "n_served", 0)) > 0]
    return {
        "ok": bool(failovers and descents and restores and recovered),
        "kill_tick": kill_tick,
        "failover_tick": (_get(failovers[0], "tick") if failovers
                          else None),
        "descend_tick": (min(_get(r, "tick") for r in descents)
                         if descents else None),
        "restore_tick": restore_tick,
        "recovered_tick": (min(_get(r, "tick") for r in recovered)
                           if recovered else None),
    }


def _telemetry_probe(env, *, n_local, n_off, ticks, off_feed, smoke,
                     base_p99):
    """Telemetry-enabled drives: the p99 overhead cell (vs the plain
    zero-fault cell already measured) and the loss+kill trace-export
    drive whose JSONL must prove the kill chain."""
    import os
    import tempfile

    from repro.obs import Telemetry, TraceRecorder

    tel = Telemetry(enabled=True)
    cell = _run_cell("zero_fault_telemetry", False, False, False, env,
                     n_local=n_local, n_off=n_off, ticks=ticks,
                     off_feed=off_feed, smoke=smoke, telemetry=tel)
    overhead = cell["p99_batch_s"] / max(base_p99, 1e-9) - 1.0
    totals = tel.counters.totals()

    tel2 = Telemetry(enabled=True)
    _run_cell("trace_drive", True, True, False, env,
              n_local=n_local, n_off=n_off, ticks=ticks,
              off_feed=off_feed, smoke=smoke, telemetry=tel2)
    with tempfile.TemporaryDirectory() as td:
        jsonl = os.path.join(td, "trace.jsonl")
        perfetto = os.path.join(td, "trace_perfetto.json")
        tel2.trace.to_jsonl(jsonl)
        tel2.trace.export_perfetto(perfetto)
        replayed = TraceRecorder.load_jsonl(jsonl)
        chain = kill_chain(replayed)
        with open(perfetto) as fh:
            pf = json.load(fh)
        perfetto_ok = (isinstance(pf.get("traceEvents"), list)
                       and len(pf["traceEvents"]) == len(replayed)
                       and all("ph" in e and "ts" in e
                               for e in pf["traceEvents"]))
    return {
        "p99_telemetry_s": cell["p99_batch_s"],
        "p99_overhead_frac": overhead,
        "counter_ticks": totals.get("serve.ticks", 0),
        "counter_delivered": totals.get("serve.frames_delivered", 0),
        "counter_link_attempts": totals.get("serve.link_attempts", 0),
        "n_trace_records": len(replayed),
        "run_id": tel2.run_id,
        "chain": chain,
        "perfetto_ok": perfetto_ok,
    }


def _bitexact_pair(ex, link, cfg, pools, ticks=3):
    """Drive the same tiny fleet with chaos=None (the PR 8 serving path)
    and with an inert ChaosSpec; compare every completion leaf."""
    from repro.camera.serve import ChaosSpec, StreamingServer

    quiet, hot = pools

    def run(chaos):
        srv = StreamingServer(ex, link=link, config=cfg, chaos=chaos)
        for k in range(4):
            dec = srv.register(f"s{k}", fps=0.5, cut="vj" if k % 2 else None,
                               bits=8 if k % 2 else None, motion_frac=0.1)
            assert dec.admitted, dec
        reps = []
        t = 0.0
        for i in range(ticks):
            for k in range(4):
                vid = hot[k % len(hot)]
                st = srv.streams[f"s{k}"]
                for j in range(cfg.chunk):
                    srv.enqueue(f"s{k}",
                                vid[(st.seq_next) % len(vid)], t=t)
            t += cfg.tick_s
            reps.append(srv.tick(t))
        return reps

    plain, inert = run(None), run(ChaosSpec())
    for rp, ri in zip(plain, inert):
        if (rp.n_served, rp.n_quiet, rp.n_requeued, rp.bytes_sent) != \
                (ri.n_served, ri.n_quiet, ri.n_requeued, ri.bytes_sent):
            return False
        if ri.shed != () or ri.n_failed_tx or ri.ladder_moves:
            return False
        for cp, ci in zip(rp.completions, ri.completions):
            if cp.sid != ci.sid or cp.seqs != ci.seqs or \
                    cp.wire_bytes != ci.wire_bytes:
                return False
            for k, v in cp.result.items():
                if not np.array_equal(np.asarray(v),
                                      np.asarray(ci.result[k])):
                    return False
    return True


def _child(mode: str):
    import dataclasses

    import jax

    from benchmarks.serving import _mean_chunk_bytes, _setup
    from repro.camera.offload import BACKSCATTER
    from repro.camera.serve import ServeConfig

    assert jax.local_device_count() == 8, "chaos sweep wants 8 fake devices"
    smoke = mode != "full"
    ex, ctl, quiet, hot, calib = _setup(smoke)
    if smoke:
        cfg = ServeConfig(chunk=2, capacity=8, slo_s=2.5, tick_s=1.0,
                          max_queue_s=8.0, resolve_every=8, link_window=2,
                          admit_util=0.9, stats_window=8,
                          max_queue_frames=5)
        n_local, n_off, ticks = 8, 16, 9
    else:
        # chunk=2 keeps the worst tick's dispatch bill under the SLO
        # even when degradation ladders hold three offload groups live
        # at once; capacity stays at the §13 full-bench 96 slots
        cfg = ServeConfig(chunk=2, capacity=96, slo_s=2.5, tick_s=1.0,
                          max_queue_s=8.0, resolve_every=32, link_window=4,
                          admit_util=0.9, stats_window=8,
                          max_queue_frames=8)
        n_local, n_off, ticks = 64, 192, 12

    # provision the uplink like the §13 bench: measured vj bytes with
    # headroom, widened for the chaos cells' retransmission inflation;
    # sized for the largest (acceptance-scale) cell of the sweep
    q_chunk_b = _mean_chunk_bytes(ex, quiet[:2], "vj", 8, cfg.chunk)
    fleet_bps = (960 if not smoke else n_off) * q_chunk_b / cfg.chunk
    link = BACKSCATTER.scaled(max(fleet_bps / 0.35, 1.0)
                              / BACKSCATTER.bytes_per_s)

    shared_steps: dict = {}
    shared_execs: dict = {}
    env = (ex, ctl, link, cfg, (quiet, hot), shared_steps, shared_execs)

    bit_cfg = dataclasses.replace(cfg, capacity=8)
    bitexact = _bitexact_pair(ex, link, bit_cfg, (quiet, hot))

    cells = []
    for label, lo, ki, br in _specs(mode):
        worst = lo and ki and br
        nl, no, tk = n_local, n_off, ticks
        if not smoke and (worst or not (lo or ki or br)):
            # acceptance cells at the 1024-stream scale
            nl, no, tk = 64, 960, 21
        # offloaded feed rate deliberately exceeds the per-stream drain
        # ceiling (one chunk per gather): bounded queues must shed, and
        # the shed must be fair and fully accounted
        cells.append(_run_cell(label, lo, ki, br, env, n_local=nl,
                               n_off=no, ticks=tk,
                               off_feed=cfg.chunk + 1,
                               smoke=smoke, server_brownout=worst))

    # §15 telemetry plane: overhead at the zero-fault cell's own scale
    # (acceptance scale in full mode) + the JSONL kill-chain proof
    zero = next(c for c in cells if c["label"] == "loss0_kill0_brown0")
    nl, no, tk = n_local, n_off, ticks
    if not smoke:
        nl, no, tk = 64, 960, 21
    telemetry = _telemetry_probe(env, n_local=nl, n_off=no, ticks=tk,
                                 off_feed=cfg.chunk + 1, smoke=smoke,
                                 base_p99=zero["p99_batch_s"])
    print(json.dumps({"mode": mode, "zero_fault_bitexact": int(bitexact),
                      "n_devices": jax.local_device_count(),
                      "cells": cells, "telemetry": telemetry}))


# ---------------------------------------------------------------------------
# parent: rows for benchmarks.run
# ---------------------------------------------------------------------------


def rows(smoke: bool = False):
    from benchmarks.timing import run_json_child

    mode = "smoke" if smoke else "full"
    data = run_json_child(["benchmarks.serving_chaos", "--child", mode],
                          n_devices=8,
                          timeout=_SMOKE_TIMEOUT if smoke
                          else _FULL_TIMEOUT)
    assert data is not None, "serving_chaos child failed"
    out = [("serving_chaos", "zero_fault_bitexact",
            str(data["zero_fault_bitexact"]),
            "inert ChaosSpec vs no chaos plane: every completion leaf "
            "bit-identical (the PR 8 serving path)")]
    worst = None
    for c in data["cells"]:
        if c["label"] == "loss1_kill1_brown1":
            worst = c
        out.append((
            "serving_chaos", f"cell_{c['label']}",
            "1" if (c["audit_ok"] and c["exactly_once"]) else "0",
            f"streams={c['n_streams']} ticks={c['ticks']} "
            f"delivered={c['delivered']} shed={c['shed']} "
            f"queued={c['queued']} failed_tx={c['failed_tx']} "
            f"ladder_moves={c['ladder_moves']} kills={c['kill_fired']} "
            f"gap={c['max_gap_ticks']}/{c['gap_bound_ticks']} "
            f"p99={c['p99_batch_s']:.3f}s "
            f"post_p99={c['post_recovery_p99_s']:.3f}s"))
    assert worst is not None, "worst cell missing from sweep"
    out.append(("serving_chaos", "worst_cell_exactly_once",
                "1" if (worst["audit_ok"] and worst["exactly_once"]) else
                "0",
                f"loss+kill+brownout at {worst['n_streams']} streams: "
                f"{worst['enqueued']} enqueued = {worst['delivered']} "
                f"delivered + {worst['shed']} shed + {worst['queued']} "
                "queued, across a server restart"))
    out.append(("serving_chaos", "server_brownout_restore",
                "1" if worst["restored_exact"] else "0",
                "checkpoint -> discard server -> restore mid-drive: "
                "seq audit identical across the restart"))
    out.append(("serving_chaos", "post_recovery_p99_s",
                f"{worst['post_recovery_p99_s']:.4f}",
                f"SLO={worst['slo_s']}s measured after device restore + "
                "brownout window"))
    out.append(("serving_chaos", "starvation_gap",
                f"{worst['max_gap_ticks']}",
                f"bound=ceil(R/capacity)+ladder_depth="
                f"{worst['gap_bound_ticks']} ticks "
                f"(R={worst['max_backlogged']})"))
    out.append(("serving_chaos", "retx_admission_factor",
                f"{worst['retx_factor']:.3f}",
                "admission bps inflation for faulty streams "
                "(1/(1-stationary_loss))"))

    assert data["zero_fault_bitexact"] == 1, \
        "inert chaos diverged from the PR 8 serving path"
    zero = next(c for c in data["cells"]
                if c["label"] == "loss0_kill0_brown0")
    assert zero["shed"] > 0, \
        "the offered overload never exercised the fair shedder"
    out.append(("serving_chaos", "overload_shed_frames",
                str(zero["shed"]),
                f"zero-fault cell, offered load above the per-stream "
                f"drain ceiling: oldest-first DRR "
                f"shed, every seq surfaced ({zero['delivered']} delivered"
                f" + {zero['shed']} shed + {zero['queued']} queued = "
                f"{zero['enqueued']})"))
    for c in data["cells"]:
        assert c["audit_ok"] and c["exactly_once"], \
            f"frame accounting broke in cell {c['label']}: {c}"
        assert c["gap_ok"], \
            f"starvation bound violated in cell {c['label']}: {c}"
    assert worst["restored_exact"], "server restore changed the audit"
    assert worst["post_recovery_slo_ok"], \
        f"post-recovery p99 {worst['post_recovery_p99_s']:.3f}s over SLO"
    kill_cells = [c for c in data["cells"] if "kill1" in c["label"]]
    assert kill_cells and all(c["kill_fired"] == 4 for c in kill_cells), \
        "device-kill schedule did not fire"
    loss_cells = [c for c in data["cells"]
                  if "loss1" in c["label"]]
    assert any(c["failed_tx"] > 0 or c["ladder_moves"] > 0
               for c in loss_cells), \
        "loss cells produced no observable fault symptoms"

    # §15 telemetry plane rows
    tel = data["telemetry"]
    chain = tel["chain"]
    out.append(("serving_chaos", "telemetry_p99_overhead_frac",
                f"{tel['p99_overhead_frac']:.4f}",
                f"p99 tick latency with §15 telemetry enabled "
                f"({tel['p99_telemetry_s']:.3f}s) vs the plain zero-fault "
                "cell; acceptance < 0.05"))
    out.append(("serving_chaos", "telemetry_counters",
                str(tel["counter_ticks"]),
                f"serve.ticks={tel['counter_ticks']} frames_delivered="
                f"{tel['counter_delivered']} link_attempts="
                f"{tel['counter_link_attempts']} (device-lazy panel, "
                "one sync at export)"))
    out.append(("serving_chaos", "trace_kill_chain",
                "1" if chain["ok"] else "0",
                f"device-kill traceable from JSONL alone: kill@t"
                f"{chain.get('kill_tick')} -> failover@t"
                f"{chain.get('failover_tick')} -> ladder-descend@t"
                f"{chain.get('descend_tick')} -> restore@t"
                f"{chain.get('restore_tick')} -> serving-again@t"
                f"{chain.get('recovered_tick')} "
                f"({tel['n_trace_records']} records, run "
                f"{tel['run_id']})"))
    out.append(("serving_chaos", "trace_perfetto_export",
                "1" if tel["perfetto_ok"] else "0",
                "chrome://tracing / Perfetto trace_event JSON: one event "
                "per JSONL record, ph/ts present on every event"))
    assert chain["ok"], f"kill chain not traceable from JSONL: {chain}"
    assert tel["perfetto_ok"], "Perfetto export malformed"
    if not smoke:
        assert tel["p99_overhead_frac"] < 0.05, \
            (f"telemetry p99 overhead {tel['p99_overhead_frac']:.3f} "
             "breaches the 5% acceptance bound")
    return out


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child(sys.argv[-1])
    else:
        for r in rows(smoke="--smoke" in sys.argv):
            print(",".join(str(c) for c in r))
