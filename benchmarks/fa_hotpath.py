"""§III frame-to-auth hot path: seed per-motion-frame Python loop vs the
single-dispatch streaming executor (BENCH_fa_hotpath.json).

Timed configurations on the paper's 176x144 security workload:

  oracle   — the seed-era funnel, one motion frame at a time in Python:
             materialize EVERY scanning window (``extract_windows``),
             per-window integral images through ``cascade_apply``, then
             numpy crops of the detections and the float fake-quantized
             NN (``forward_quantized``) — host round-trips between every
             stage.  Timed warm on a few motion frames and extrapolated
             (the full video takes minutes), like vr_depth_hotpath's
             oracle pairs.
  hostloop — the pre-executor production path (what the example shipped
             between PR 2 and this PR): batched ``FusedDetector.detect``
             for VJ, but windows still cropped on numpy per frame and the
             NN still eager fake-quantization on host.
  fused    — ``FaceAuthExecutor``: motion gate, frame compaction, fused
             detection, capacity-padded window gathers and the int8
             Pallas-kernel NN tail in ONE jit dispatch per batch.
  multi    — the same executor vmapped over N independent camera streams
             on one device, and (subprocess, one stream per device — the
             WISPCam-fleet shape) pmapped across 8 host devices.

Funnel parity is part of the benchmark: the executor must report
*identical* motion/window/auth counts to the host loop (the loop's NN
re-run through ``nn_forward_quantized`` for the count comparison, since
int8-vs-fake-quant scores differ at the ~1e-2 level, which the score
rows report explicitly).
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

from benchmarks.timing import run_json_child, timed as _timed

N_STREAMS = 4                      # vmap fleet on one device
N_DEVICES = 8                      # pmap fleet (subprocess)


def _workload(smoke: bool = False):
    from benchmarks.workloads import fa_cascade, fa_scan
    from repro.camera.face_nn import train_face_nn
    from repro.camera.synthetic import face_dataset, security_video

    if smoke:
        frames, truth = security_video(n_frames=10, motion_frames=5, seed=1)
        casc = fa_cascade(smoke=True)
        X, y, _ = face_dataset(n_per_class=80, seed=3)
        nn = train_face_nn(X, y, steps=60)
    else:
        frames, truth = security_video()
        casc = fa_cascade(frames=frames, truth=truth)
        X, y, _ = face_dataset(n_per_class=400, seed=3)
        nn = train_face_nn(X, y, steps=1500)
    sf, st, ad = fa_scan(smoke)
    return frames, casc, nn, dict(scale_factor=sf, step=st, adaptive=ad)


def _save_workload(path, frames, casc, nn, scan):
    """Serialize (cascade, nn, frames) so the pmap child skips retraining."""
    np.savez(
        path, frames=frames,
        feats=np.array([(f.kind, f.y, f.x, f.h, f.w) for f in casc.feats],
                       np.int32),
        thresholds=casc.thresholds, polarity=casc.polarity,
        alphas=casc.alphas, stage_sizes=np.array(casc.stage_sizes),
        stage_thresholds=casc.stage_thresholds,
        w1=np.asarray(nn.w1), b1=np.asarray(nn.b1),
        w2=np.asarray(nn.w2), b2=np.asarray(nn.b2),
        scan=np.array([scan["scale_factor"], scan["step"],
                       float(scan["adaptive"])]))


def _load_workload(path):
    import jax.numpy as jnp

    from repro.camera.face_nn import FaceNN
    from repro.camera.viola_jones import Cascade, HaarFeature

    z = np.load(path)
    casc = Cascade(
        feats=[HaarFeature(*map(int, row)) for row in z["feats"]],
        thresholds=z["thresholds"], polarity=z["polarity"],
        alphas=z["alphas"], stage_sizes=[int(s) for s in z["stage_sizes"]],
        stage_thresholds=z["stage_thresholds"])
    nn = FaceNN(w1=jnp.asarray(z["w1"]), b1=jnp.asarray(z["b1"]),
                w2=jnp.asarray(z["w2"]), b2=jnp.asarray(z["b2"]))
    sf, st, ad = z["scan"]
    scan = dict(scale_factor=float(sf), step=float(st), adaptive=bool(ad))
    return z["frames"], casc, nn, scan


def _fleet_child():
    """Runs under --xla_force_host_platform_device_count=8: one stream per
    device through the pmapped executor; prints one JSON line."""
    import jax
    import jax.numpy as jnp

    from repro.camera.pipelines import FaceAuthExecutor

    frames, casc, nn, scan = _load_workload(sys.argv[-1])
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2], **scan)
    ex.calibrate(frames)
    streams = jnp.stack([jnp.asarray(np.roll(frames, 5 * s, axis=0))
                         for s in range(N_DEVICES)])
    t, _ = _timed(lambda: ex.run_streams(streams))
    print(json.dumps({
        "fleet_ms": 1e3 * t, "n_devices": jax.local_device_count(),
        "frames_per_s": N_DEVICES * len(frames) / t}))


def _fleet_ms(frames, casc, nn, scan):
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "workload.npz")
        _save_workload(path, frames, casc, nn, scan)
        return run_json_child(
            ["benchmarks.fa_hotpath", "--fleet-child", path],
            n_devices=N_DEVICES)


def rows(smoke: bool = False, n_oracle_frames: int = 2):
    import jax
    import jax.numpy as jnp

    from repro.camera.face_nn import forward_quantized, make_sigmoid_lut
    from repro.camera.pipelines import FaceAuthExecutor
    from repro.camera.viola_jones import (
        cascade_apply, extract_windows, scan_positions)
    from repro.kernels.quant_matmul.ops import nn_forward_quantized

    out = []
    frames, casc, nn, scan = _workload(smoke)
    lut, meta = make_sigmoid_lut()
    h, w = frames.shape[1:]

    # ---- fused: the streaming executor, one dispatch per batch --------------
    ex = FaceAuthExecutor(casc, nn, h, w, **scan)
    fcap, wcap, caps = ex.calibrate(frames)
    fj = jnp.asarray(frames)
    t_fused, res = _timed(lambda: ex(fj))
    fused_ms = 1e3 * t_fused / len(frames)

    # ---- multi-stream: vmapped fleet on one device --------------------------
    streams = jnp.stack([jnp.asarray(np.roll(frames, 5 * s, axis=0))
                         for s in range(N_STREAMS)])
    t_multi, _ = _timed(lambda: ex.run_streams(streams))
    multi_fps = N_STREAMS * len(frames) / t_multi

    # ---- pmap fleet: one stream per device (subprocess) ---------------------
    fleet = None if smoke else _fleet_ms(frames, casc, nn, scan)

    # ---- hostloop: the pre-executor production path -------------------------
    from benchmarks.workloads import host_loop_funnel

    fq_fn = lambda x: forward_quantized(nn, jnp.asarray(x), 8, lut, meta)
    int8_fn = lambda x: nn_forward_quantized(ex.qnn, jnp.asarray(x), lut,
                                             meta, use_pallas=False)
    # _timed performs its own untimed warm call (compile det batch)
    t_host, host_out = _timed(lambda: host_loop_funnel(ex, frames, fq_fn),
                              reps=2)
    host_ms = 1e3 * t_host / len(frames)

    # parity uses the SAME int8 datapath on the host loop (fake-quant scores
    # differ from int8 at the 1e-2 level; reported separately below); the
    # timed run's detection/crop pass feeds both NNs
    s_fq = host_out[3]
    mask, n_win_l, n_auth_l, s_int8, _prep = host_loop_funnel(
        ex, frames, int8_fn, prepared=host_out[4])
    midx = np.where(mask)[0]

    # ---- oracle: the seed per-motion-frame Python funnel --------------------
    pos = scan_positions(h, w, scan["scale_factor"], scan["step"],
                         scan["adaptive"])
    n_orc = min(n_oracle_frames, len(midx)) or 1
    orc_idx = midx[:n_orc] if len(midx) else [1]

    def oracle_frame(i):
        wins = extract_windows(frames[i], pos)
        accepted, _ = cascade_apply(casc, jnp.asarray(wins))
        dets = [pos[k] for k in np.where(np.asarray(accepted))[0]]
        if dets:
            crops = extract_windows(frames[i], dets)
            np.asarray(forward_quantized(
                nn, jnp.asarray(crops.reshape(len(crops), -1)), 8, lut, meta))
        return dets

    oracle_frame(int(orc_idx[0]))                       # warm per-op caches
    t0 = time.time()
    for i in orc_idx:
        oracle_frame(int(i))
    t_orc_motion = (time.time() - t0) / n_orc
    # amortized per source frame: only motion frames pay the funnel
    oracle_ms = 1e3 * t_orc_motion * len(midx) / len(frames)

    # ---- parity -------------------------------------------------------------
    r_motion = np.asarray(res.motion)
    r_nwin = np.asarray(res.n_windows)
    r_nauth = np.asarray(res.n_auth)
    score_diff = 0.0
    fq_diff = 0.0
    score_mismatch = False
    for i in s_int8:
        v = np.asarray(res.window_valid[i])
        se = np.sort(np.asarray(res.scores[i])[v])
        if se.shape != s_int8[i].shape:
            # capacity drops shrank one side; the MISMATCH row below must
            # still print instead of crashing on a broadcast error
            score_mismatch = True
            continue
        if se.size:
            score_diff = max(score_diff,
                             float(np.abs(se - np.sort(s_int8[i])).max()))
            fq_diff = max(fq_diff, float(
                np.abs(np.sort(s_fq[i]) - np.sort(s_int8[i])).max()))
    parity = (not score_mismatch
              and np.array_equal(r_motion, mask)
              and np.array_equal(r_nwin, n_win_l)
              and np.array_equal(r_nauth, n_auth_l))

    # ---- rows ---------------------------------------------------------------
    out.append(("fa_hotpath", "workload",
                f"{len(frames)}x{h}x{w}, {len(midx)} motion, "
                f"{int(r_nwin.sum())} windows, {int(r_nauth.sum())} auth",
                f"scan={scan} capacities f={fcap} w={wcap} vj={caps}"))
    out.append(("fa_hotpath", "oracle_ms_per_frame", f"{oracle_ms:.1f}",
                f"seed per-motion-frame loop (extract_windows + "
                f"cascade_apply + fake-quant NN), {n_orc} frames timed"))
    out.append(("fa_hotpath", "hostloop_ms_per_frame", f"{host_ms:.2f}",
                "pre-executor path: batched FusedDetector + numpy crops + "
                "eager fake-quant NN"))
    out.append(("fa_hotpath", "fused_ms_per_frame", f"{fused_ms:.2f}",
                "FaceAuthExecutor, one jit dispatch per batch"))
    out.append(("fa_hotpath", "speedup_vs_oracle",
                f"{oracle_ms / fused_ms:.1f}x", "acceptance: >= 10x"))
    out.append(("fa_hotpath", "speedup_vs_hostloop",
                f"{host_ms / fused_ms:.1f}x",
                "both share the fused detector, so single-stream is "
                "detection-compute-bound and ~1x is expected on a CPU host "
                "(the executor pays frame-capacity padding, the loop pays "
                "host syncs); the executor's win is the multi-stream rows"))
    out.append(("fa_hotpath", "single_stream_fps", f"{1e3 / fused_ms:.0f}",
                f"source rate is 1 FPS/camera -> one device sustains "
                f"~{1e3 / fused_ms:.0f} cameras"))
    out.append(("fa_hotpath", "multi_stream_fps_vmap", f"{multi_fps:.0f}",
                f"{N_STREAMS} feeds vmapped on one device"))
    if fleet:
        out.append(("fa_hotpath", "multi_stream_fps_pmap",
                    f"{fleet['frames_per_s']:.0f}",
                    f"{N_DEVICES} feeds, one per device "
                    f"({fleet['n_devices']} host devices)"))
    elif not smoke:
        out.append(("fa_hotpath", "multi_stream_fps_pmap", "unavailable",
                    "fleet subprocess failed; vmap row above is the "
                    "multi-stream number"))
    out.append(("fa_hotpath", "funnel_count_parity",
                "identical" if parity else "MISMATCH",
                "motion/window/auth counts, executor vs host loop "
                "(int8 NN on both)"))
    out.append(("fa_hotpath", "score_parity_int8",
                f"{score_diff:.2e}",
                "executor vs host-loop nn_forward_quantized (same datapath)"))
    out.append(("fa_hotpath", "score_delta_vs_fake_quant", f"{fq_diff:.3f}",
                "int8 static scales vs forward_quantized per-tensor "
                "fake-quant — the quantization-scheme gap, not an error"))
    out.append(("fa_hotpath", "capacity_drops",
                f"motion={int(np.asarray(res.motion_dropped))} "
                f"windows={int(np.asarray(res.windows_dropped).sum())} "
                f"cascade={int(np.asarray(res.cascade_dropped).sum())}",
                "0 = calibrated capacities lossless on this workload"))
    return out


def main():
    if "--fleet-child" in sys.argv:
        _fleet_child()
        return
    smoke = "--smoke" in sys.argv
    for row in rows(smoke=smoke):
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
