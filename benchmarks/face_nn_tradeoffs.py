"""Paper §III-A: NN topology / precision / geometry tradeoffs.

  topo   — input window & hidden width sweep: accuracy vs energy; the
           5x5-input NN is cheap but inaccurate, 20x20 (400-8-1) is the
           paper's accuracy/energy pick; halving error costs ~an order of
           magnitude in energy
  lut    — 256-entry LUT sigmoid vs exact (negligible)
  bits   — 16/8/4-bit datapath: 8-bit ~ 16-bit, 4-bit past the knee;
           8-bit = 41% power reduction (Table I anchor)
  pes    — PE-count geometry: energy/window minimized at 8 PEs
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.camera.face_nn import (
    classification_error,
    forward_float,
    forward_lut,
    forward_quantized,
    make_sigmoid_lut,
    nn_energy_per_window,
    nn_power,
    train_face_nn,
)
from repro.camera.synthetic import face_dataset


def _hard_dataset(size, seed=0):
    """Harder setting: heavy jitter/lighting so errors land in the paper's
    few-percent regime rather than saturating at 0."""
    X, y, _ = face_dataset(n_per_class=420, n_identities=40, size=size,
                           seed=seed)
    rng = np.random.default_rng(seed + 99)
    X = np.clip(X + rng.normal(0, 0.10, X.shape).astype(np.float32), 0, 1)
    n = int(0.9 * len(X))
    return X[:n], y[:n], X[n:], y[n:]


def rows(smoke: bool = False):
    out = []
    lut, meta = make_sigmoid_lut()
    steps = 60 if smoke else 1500
    topos = ([(5, 8), (20, 8)] if smoke
             else [(5, 8), (10, 8), (20, 4), (20, 8), (20, 16)])

    # ---- topology sweep -----------------------------------------------------
    errs = {}
    for size, hidden in topos:
        Xtr, ytr, Xte, yte = _hard_dataset(size, seed=1)
        nn = train_face_nn(Xtr, ytr, n_hidden=hidden, steps=steps, seed=0)
        err = classification_error(forward_float(nn, jnp.asarray(Xte)), yte)
        e = nn_energy_per_window(nn.macs)
        errs[(size, hidden)] = (err, e)
        out.append(("topo", f"{size}x{size}-{hidden}-1",
                    f"err={err*100:.1f}%", f"energy={e*1e9:.1f} nJ/window"))
    if not smoke:                 # 60-step smoke nets are too undertrained
        assert errs[(5, 8)][0] > errs[(20, 8)][0], "5x5 must be worse (paper)"
        out.append(("topo", "ordering_check",
                    f"5x5 err {errs[(5,8)][0]*100:.1f}% > 20x20 err {errs[(20,8)][0]*100:.1f}%",
                    "paper: larger input window => significant accuracy gain"))

    # ---- LUT sigmoid + datapath width (on the 400-8-1 pick) ------------------
    Xtr, ytr, Xte, yte = _hard_dataset(20, seed=2)
    nn = train_face_nn(Xtr, ytr, n_hidden=8, steps=60 if smoke else 3000,
                       seed=0)
    Xte_j = jnp.asarray(Xte)
    err_f = classification_error(forward_float(nn, Xte_j), yte)
    err_lut = classification_error(forward_lut(nn, Xte_j, lut, meta), yte)
    out.append(("lut", "float_vs_lut",
                f"{err_f*100:.2f}% vs {err_lut*100:.2f}%",
                "paper: negligible"))
    for bits in (16, 8, 4):
        err_q = classification_error(
            forward_quantized(nn, Xte_j, bits, lut, meta), yte)
        out.append(("bits", f"{bits}-bit",
                    f"err={err_q*100:.2f}% (delta {abs(err_q-err_f)*100:.2f}%)",
                    f"power={nn_power(bits)*1e6:.0f} uW "
                    f"({'paper: ~0.4% loss' if bits == 8 else 'paper: >1% loss' if bits == 4 else ''})"))
    out.append(("bits", "power_reduction_16to8",
                f"{100*(1 - nn_power(8)/nn_power(16)):.0f}%", "paper: 41%"))

    # ---- PE geometry ----------------------------------------------------------
    for pes in (2, 4, 8, 16, 32):
        e = nn_energy_per_window(nn.macs, n_pes=pes)
        out.append(("pes", f"{pes}_pes", f"{e*1e9:.1f} nJ/window",
                    "paper optimum: 8"))
    energies = {p: nn_energy_per_window(nn.macs, n_pes=p) for p in (2, 4, 8, 16, 32)}
    out.append(("pes", "optimum", str(min(energies, key=energies.get)),
                "paper: 8 PEs"))
    return out


def main():
    for row in rows():
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
