"""Shared measurement plumbing for the hot-path benchmarks.

Warm-then-average timing lives in ``repro.core.timing`` (one
implementation shared with the offload cut controller; re-exported here
for the benchmark modules); this module adds the "subprocess with N fake
CPU host devices" launcher used by both vr_depth_hotpath (rig pmap) and
fa_hotpath (stream-fleet pmap).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.core.timing import block, timed  # noqa: F401  (re-export)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_json_child(args, n_devices: int = 8, timeout: int = 900):
    """Run ``python -m <args...>`` with ``n_devices`` fake CPU host devices
    and parse its last stdout line as JSON; None if the child failed."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-m", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout)
    if out.returncode != 0:
        return None
    return json.loads(out.stdout.strip().splitlines()[-1])
