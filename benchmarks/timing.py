"""Shared measurement plumbing for the hot-path benchmarks.

One implementation of warm-then-average timing and of the
"subprocess with N fake CPU host devices" launcher, used by both
vr_depth_hotpath (rig pmap) and fa_hotpath (stream-fleet pmap) — a fix
here (blocking semantics, env setup, error handling) reaches every
benchmark at once.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def block(out):
    """Block until every device array in ``out`` is ready (pytrees and
    result dataclasses alike)."""
    import jax

    jax.block_until_ready(vars(out) if dataclasses.is_dataclass(out)
                          else out)


def timed(fn, *args, reps: int = 3):
    """(seconds_per_rep, last_output): one warm call (compile + caches),
    then ``reps`` timed calls, blocking on device completion."""
    out = fn(*args)
    block(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    block(out)
    return (time.time() - t0) / reps, out


def run_json_child(args, n_devices: int = 8, timeout: int = 900):
    """Run ``python -m <args...>`` with ``n_devices`` fake CPU host devices
    and parse its last stdout line as JSON; None if the child failed."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-m", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout)
    if out.returncode != 0:
        return None
    return json.loads(out.stdout.strip().splitlines()[-1])
