"""``analysis`` benchmark section: the static contract gate as a report.

Runs the repro.analysis pass families over the registered universe and
emits per-family subject/finding counts plus the single number that
matters: ``non_baselined`` (must be 0 — same contract tier-1 enforces via
tests/test_analysis.py).

Smoke mode runs only the spec-level families (kernel legality +
cut soundness): they cover every kernel package and every declared cut in
a couple of seconds, while the jaxpr families re-trace all 36 executor
targets (minutes of cascade/NN setup) — that full sweep belongs to the
non-smoke run and the tier-1 gate test.
"""

from __future__ import annotations


def rows(smoke: bool = False):
    from repro.analysis import run_analysis
    from repro.analysis.report import Baseline

    only = ("kernel", "cut") if smoke else None
    report = run_analysis(only=only)
    baseline = Baseline.load()
    out = []
    for res in report.results:
        out.append(("analysis", f"{res.family}_subjects", len(res.subjects),
                    "analyzed units"))
        out.append(("analysis", f"{res.family}_findings", len(res.findings),
                    "total (incl. baselined)"))
    new = report.new_findings(baseline)
    out.append(("analysis", "baselined", len(report.findings) - len(new),
                "accepted via analysis/baseline.json"))
    out.append(("analysis", "non_baselined", len(new),
                "gate: must be 0" + (" (smoke: kernel+cut only)" if smoke
                                     else "")))
    return out
