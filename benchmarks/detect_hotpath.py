"""Detection hot-path: seed-style per-window front-end vs the frame-resident
fused gather path (one integral image + compacting cascade).

Three timed configurations on the paper's 176x144 security workload:

  old   — the seed ``detect_faces`` dataflow: materialize ~25.8k resampled
          20x20 windows (extract_windows), per-window integral images,
          Python loop over features (cascade_apply), no early-exit savings;
  ref   — the scaled-feature golden oracle (detect_faces), same per-window
          structure with native-resolution windows;
  new   — FusedDetector: one frame integral, gathered Haar corner taps,
          compacting cascade with measured capacities.

Also reports the FLOP saving compaction realizes vs the masked oracle —
the paper's "86% fewer invocations" finally charged in real work, not
just counted.
"""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.camera.synthetic import security_video
from repro.camera.viola_jones import (
    FusedDetector, cascade_apply, detect_faces, extract_windows,
    scan_positions)
from repro.core.cascade import compaction_work


def _detect_seed_path(casc, frame, scan=(1.25, 0.025, True)):
    """The seed repo's detect_faces dataflow, kept verbatim for old-vs-new
    timing (resample-to-20x20 semantics; superseded by scaled features)."""
    pos = scan_positions(frame.shape[0], frame.shape[1], *scan)
    wins = extract_windows(frame, pos)
    accepted, _ = cascade_apply(casc, jnp.asarray(wins))
    return [pos[i] for i in np.where(np.asarray(accepted))[0]]


def rows(n_old_frames: int = 2, n_ref_frames: int = 2, smoke: bool = False):
    out = []
    from benchmarks.workloads import fa_cascade, fa_scan
    if smoke:
        frames, truth = security_video(n_frames=6, motion_frames=3, seed=1)
        casc = fa_cascade(smoke=True)
        n_old_frames = n_ref_frames = 1
    else:
        frames, truth = security_video()
        casc = fa_cascade(frames=frames, truth=truth)
    scan = fa_scan(smoke)

    h, w = frames.shape[1:]
    det = FusedDetector(casc, h, w, scale_factor=scan[0], step=scan[1],
                        adaptive=scan[2])
    det.calibrate(frames[:4])
    det.detect(frames)                       # compile + warm
    t0 = time.time()
    dets, stats = det.detect(frames)
    new_fps = len(frames) / (time.time() - t0)

    t0 = time.time()
    for i in range(n_old_frames):
        _detect_seed_path(casc, frames[i], scan)
    old_fps = n_old_frames / (time.time() - t0)

    t0 = time.time()
    ref_sets = {i: set(detect_faces(casc, frames[i], *scan)[0])
                for i in range(n_ref_frames)}
    ref_fps = n_ref_frames / (time.time() - t0)

    ident = sum(set(dets[i]) == ref_sets[i] for i in ref_sets)
    stage_cost = [sz * (8 + 2) for sz in det.tables.stage_sizes]
    masked, compacted = compaction_work(stage_cost, stats["n_windows"],
                                        det.capacities)
    out.append(("detect", "windows_per_frame", stats["n_windows"],
                "176x144, scale 1.25, adaptive 2.5%"))
    out.append(("detect", "old_fps", f"{old_fps:.2f}",
                f"seed per-window path, {n_old_frames} frames"))
    out.append(("detect", "ref_fps", f"{ref_fps:.2f}",
                f"scaled-feature golden oracle, {n_ref_frames} frames"))
    out.append(("detect", "new_fps", f"{new_fps:.1f}",
                f"fused gathers + compaction, {len(frames)} frames steady"))
    out.append(("detect", "speedup_vs_seed", f"{new_fps / old_fps:.1f}x",
                "acceptance: >= 10x"))
    out.append(("detect", "identical_detections",
                f"{ident}/{len(ref_sets)} frames vs oracle",
                "isolated fp-borderline stumps may flip single windows"))
    out.append(("detect", "capacities",
                "/".join(str(c) for c in det.capacities),
                "from measured stage selectivities (calibrate)"))
    out.append(("detect", "flops_masked_oracle", f"{masked:.4g}",
                "per frame: every stage on every window"))
    out.append(("detect", "flops_compacted", f"{compacted:.4g}",
                f"{100 * (1 - compacted / masked):.0f}% fewer "
                "(paper: 86% fewer invocations)"))
    out.append(("detect", "stage_evals_per_frame",
                stats["stage_evals"] // len(frames),
                "data-dependent count the energy model charges"))
    out.append(("detect", "capacity_drops", stats["dropped"],
                "0 = compaction lossless on this workload"))
    return out


def main():
    for row in rows():
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
