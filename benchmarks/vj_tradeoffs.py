"""Paper Fig. 4c: VJ parameter sweep — scale factor x step size x adaptive.

Reports precision / recall / F1 (normalized to the finest setting) and
classifier invocations; checks the paper's two findings:
  * the knobs move RECALL, not precision;
  * (scale 1.25, adaptive 2.5%) cuts invocations ~86% with no accuracy loss.
"""

from __future__ import annotations

from repro.camera.synthetic import security_video
from repro.camera.viola_jones import detect_faces_batch


def _eval(casc, frames, truth, scale, step, adaptive):
    """Sweep point via the fused front-end (identical detections to the
    reference path; tests/test_detect.py pins the equivalence)."""
    dets_all, stats = detect_faces_batch(casc, frames, scale, step, adaptive)
    if stats["dropped"]:
        # capacity overflow would silently delete detections and corrupt
        # the accuracy rows this sweep exists to produce: redo this sweep
        # point with the masked oracle (full capacities), one frame at a
        # time to bound the gather working set at fine scan settings.
        dets_all = [detect_faces_batch(casc, f, scale, step, adaptive,
                                       capacities=None)[0][0]
                    for f in frames]
    invocations = stats["n_invocations"]
    tp = fp = fn = 0
    for info, dets in zip(truth, dets_all):
        matched = set()
        for (fy, fx, _s) in info["faces"]:
            hit = any(abs(dy - fy) < 12 and abs(dx - fx) < 12
                      for (dy, dx, _w) in dets)
            tp += 1 if hit else 0
            fn += 0 if hit else 1
        for (dy, dx, _w) in dets:
            near = any(abs(dy - fy) < 12 and abs(dx - fx) < 12
                       for (fy, fx, _s) in info["faces"])
            fp += 0 if near else 1
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    return prec, rec, f1, invocations


def rows(n_frames: int = 12, smoke: bool = False):
    out = []
    if smoke:
        n_frames = 4
    frames, truth = security_video(n_frames=n_frames,
                                   motion_frames=min(8, n_frames - 2), seed=1)
    from benchmarks.workloads import SMOKE_SCAN, fa_cascade
    casc = (fa_cascade(smoke=True) if smoke
            else fa_cascade(frames=frames, truth=truth))
    out.append(("cascade", "structure",
                f"{casc.n_stages} stages x {casc.stage_sizes[0]}",
                "Table I: 10x33"))
    # only frames with faces matter for the sweep; keep all for FP counting
    # reference point = (1.05, step 2): the paper's conventional baseline is
    # (1.1, step 1); step 2 at scale 1.05 keeps the sweep tractable on one
    # CPU core while preserving the invocation-count ratios the claim is
    # about (both axes still span the paper's ranges).
    settings = [
        ("conventional_1.1_step1", 1.1, 1, False),   # the paper's baseline
        ("scale1.25_step2", 1.25, 2, False),
        ("scale1.25_adaptive2.5%", 1.25, 0.025, True),
        ("scale1.5_adaptive5%", 1.5, 0.05, True),
        ("scale2.0_step16", 2.0, 16, False),
    ]
    if smoke:                       # two coarse points keep the sweep alive
        settings = [("smoke_scan", *SMOKE_SCAN),
                    ("scale2.0_step16", 2.0, 16, False)]
    base = None
    for name, scale, step, adaptive in settings:
        p, r, f1, inv = _eval(casc, frames, truth, scale, step, adaptive)
        if base is None:
            base = (p, r, f1, inv)
        out.append(("fig4c", name,
                    f"P={p:.2f} R={r/max(base[1],1e-9):.2f}(norm) F1={f1:.2f}",
                    f"invocations={inv} ({100*(1-inv/base[3]):.0f}% fewer)"))
    if smoke:
        return out
    # the paper's chosen point
    p, r, f1, inv = _eval(casc, frames, truth, 1.25, 0.025, True)
    out.append(("fig4c", "paper_pick_check",
                f"recall_ratio={r/max(base[1],1e-9):.2f}",
                f"invocation_reduction={100*(1-inv/base[3]):.0f}% (paper: 86%)"))
    return out


def main():
    for row in rows():
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
