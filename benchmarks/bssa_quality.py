"""Paper Fig. 11b: bilateral grid size vs depth quality (MS-SSIM).

Sweeps pixels-per-grid-vertex in {4, 8, 16, 32, 64} at two input
resolutions; checks the paper's finding that grid size matters more than
input resolution, and that small grids (coarse = many pixels per vertex
relative to structure) degrade quality.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.camera.bssa import GridSpec, bssa_depth, ms_ssim, rough_disparity
from repro.camera.synthetic import stereo_pair


def _quality(h, w, sigma, seed=2):
    left, right, disp_gt = stereo_pair(h=h, w=w, seed=seed)
    depth = bssa_depth(jnp.asarray(left), jnp.asarray(right),
                       GridSpec(sigma_spatial=sigma), max_disp=12, n_iters=8)
    d = np.asarray(depth)
    gt = disp_gt
    dn = (d - d.min()) / (np.ptp(d) + 1e-9)
    gn = (gt - gt.min()) / (np.ptp(gt) + 1e-9)
    return ms_ssim(jnp.asarray(dn), jnp.asarray(gn))


def rows(smoke: bool = False):
    out = []
    res = {"256x320": (256, 320), "128x160": (128, 160)}
    sigmas = (4, 8, 16, 32, 64)
    if smoke:
        res = {"64x80": (64, 80), "48x64": (48, 64)}
        sigmas = (8, 16)
    table = {}
    for rname, (h, w) in res.items():
        for sigma in sigmas:
            if sigma * 4 > min(h, w):
                continue
            q = _quality(h, w, sigma)
            table[(rname, sigma)] = q
            out.append(("fig11b", f"{rname}_sigma{sigma}", f"msssim={q:.3f}", ""))

    # paper claims: grid size drives quality more than input resolution
    hi_name = next(iter(res))
    hi = [v for (r, s), v in table.items() if r == hi_name]
    spread_grid = max(hi) - min(hi)
    per_sigma = {}
    for (r, s), v in table.items():
        per_sigma.setdefault(s, []).append(v)
    spread_res = np.mean([max(vs) - min(vs) for vs in per_sigma.values()
                          if len(vs) == 2])
    out.append(("fig11b", "grid_vs_resolution_sensitivity",
                f"grid-spread={spread_grid:.3f} res-spread={spread_res:.3f}",
                "paper: grid size > input resolution"))
    return out


def main():
    for row in rows():
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
