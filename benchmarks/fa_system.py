"""Paper §III-D reproduction: the face-authentication system tables.

Outputs (CSV-ish rows; EXPERIMENTS.md quotes them):
  fig8   — total power per pipeline configuration (ASIC + CPU variants)
  fig9   — compute-vs-comm walk along the full pipeline; checks +28%
  accel  — speedup & energy vs MSP430 software (paper: 265x / 442,146x)
  knobs  — 2.68x comm crossover + window-rate (8 MP) crossover
  funnel — workload funnel (62 frames -> 12 motion -> ~40 windows, 0 missed
           true faces) measured end-to-end on the synthetic security video
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.camera.pipelines import (
    FAWorkloadStats,
    calibrate_fa,
    fa_pipeline,
    FRAME_BYTES,
    NN_MACS,
    WINDOW_PIXELS,
)
from repro.camera.face_nn import (
    NN_FREQ_HZ,
    nn_energy_per_window,
    nn_time_per_window,
)
from repro.core.costmodel import (
    HardwareProfile,
    IMAGE_SENSOR,
    MOTION_ASIC,
    MSP430,
    NN_ASIC,
    VJ_ASIC,
    energy_cost,
)
from repro.core.placement import solve_cut


def rows(smoke: bool = False):
    """``smoke=True`` keeps every row but measures the funnel on a toy
    cascade/video (seconds, offline) — CI liveness, not quotable numbers."""
    out = []
    stats = FAWorkloadStats()
    cal = calibrate_fa(stats)
    link = cal.rf_link()
    pipe = fa_pipeline(stats)

    profiles = {
        "sensor": IMAGE_SENSOR,
        "motion": MOTION_ASIC,
        "vj": HardwareProfile("vj_asic", flops_per_s=VJ_ASIC.flops_per_s,
                              p_active_w=VJ_ASIC.p_active_w,
                              p_leak_w=VJ_ASIC.p_leak_w),
        "nn": HardwareProfile("nn_asic", flops_per_s=NN_ASIC.flops_per_s,
                              p_active_w=cal.nn_effective_w,
                              p_leak_w=cal.nn_effective_w),
    }
    # duty model: sensor/motion always on; VJ leakage-resident; NN calibrated
    duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}

    # ---- Fig. 8: configuration ladder --------------------------------------
    configs = [
        ("raw_offload", (), "sensor"),
        ("motion_only", ("motion",), "motion"),
        ("motion+vj_offload_nn", ("motion", "vj"), "vj"),
        ("full_pipeline", ("motion", "vj"), "nn"),
    ]
    fig8 = {}
    for name, opts, cut in configs:
        rep = energy_cost(pipe.configure(opts), profiles, link, cut,
                          duties=duties, config_name=name)
        fig8[name] = rep
        out.append(("fig8", name, f"{rep.total_w*1e6:.1f} uW",
                    f"compute={rep.compute_w*1e6:.1f} comm={rep.comm_w*1e6:.1f}"))

    # CPU (MSP430) face-auth variants: NN per-window energy scaled by the
    # measured accelerator ratio; the MSP430 cannot meet 1 FPS (paper) —
    # report the power it WOULD need.
    e_nn_asic = nn_energy_per_window(NN_MACS)
    e_nn_cpu = e_nn_asic * 442_146.0
    t_nn_cpu = nn_time_per_window(NN_MACS) * 265.0
    wps_filtered = stats.nn_windows_per_second
    wps_raw = stats.scan_windows_per_frame          # every window, no filters
    cpu_full_filtered = (cal.base_compute_w + e_nn_cpu * wps_filtered)
    cpu_raw = (IMAGE_SENSOR.p_active_w + e_nn_cpu * wps_raw)
    out.append(("fig8", "cpu_nn_after_filters", f"{cpu_full_filtered*1e6:.1f} uW",
                f"{cpu_full_filtered/fig8['full_pipeline'].total_w:.0f}x full-ASIC"))
    out.append(("fig8", "cpu_nn_no_filters", f"{cpu_raw*1e6:.1f} uW",
                f"{cpu_raw/fig8['full_pipeline'].total_w:.0f}x full-ASIC"))
    out.append(("fig8", "cpu_orders_of_magnitude",
                f"{np.log10(cpu_full_filtered/fig8['full_pipeline'].total_w):.1f}..."
                f"{np.log10(cpu_raw/fig8['full_pipeline'].total_w):.1f}",
                "paper: 2-5 orders"))

    # ---- Fig. 9: +28% when the NN moves in-camera --------------------------
    plus = (fig8["full_pipeline"].total_w / fig8["motion+vj_offload_nn"].total_w - 1)
    out.append(("fig9", "nn_in_camera_delta", f"+{plus*100:.1f}%",
                "paper: +28%"))
    best = min(fig8.values(), key=lambda r: r.total_w)
    out.append(("fig9", "lowest_power_config", best.config_name,
                "paper: motion+FD filters, offload NN"))

    # solver agrees with the enumeration
    sol = solve_cut(pipe, profiles, link, regime="energy", duties=duties)
    out.append(("fig9", "solver_pick", sol.report.config_name,
                f"{sol.report.total_w*1e6:.1f} uW"))

    # ---- accelerator gains (paper: 265x speedup, 442,146x energy) ----------
    out.append(("accel", "nn_speedup_vs_msp430", "265.0x", "by construction: "
                "MSP430 energy/latency anchored to the paper's measured ratios"))
    out.append(("accel", "nn_energy_ratio", "442146x", "anchor (Table I-derived)"))
    out.append(("accel", "nn_asic_energy_per_window",
                f"{e_nn_asic*1e9:.2f} nJ", f"@{NN_FREQ_HZ/1e6:.1f} MHz"))

    # ---- decision knobs -----------------------------------------------------
    # comm-cost crossover: scale e_c until full_pipeline beats offload
    lo, hi = 1.0, 10.0
    for _ in range(60):
        mid = (lo + hi) / 2
        link2 = HardwareProfile("rf", joules_per_byte=cal.rf_joules_per_byte * mid)
        a = energy_cost(pipe.configure(("motion", "vj")), profiles, link2,
                        "vj", duties=duties).total_w
        b = energy_cost(pipe.configure(("motion", "vj")), profiles, link2,
                        "nn", duties=duties).total_w
        if b < a:
            hi = mid
        else:
            lo = mid
    out.append(("knobs", "comm_crossover", f"{hi:.2f}x",
                "paper: 2.68x"))

    # window-rate crossover (the paper's >=8 MP point): scale the windows/s
    # reaching the NN until in-camera wins.  Under calibration the crossover
    # rate equals 2.68x the base rate; the paper attributes reaching it to
    # 8 MP sensors => implied window-count scaling exponent vs pixels:
    base_wps = stats.nn_windows_per_second
    scale = 2.68
    px_ratio = 8e6 / (176 * 144)
    gamma = np.log(scale) / np.log(px_ratio)
    out.append(("knobs", "window_rate_crossover",
                f"{scale:.2f}x base ({scale*base_wps:.2f} win/s)",
                f"implied window~pixels^{gamma:.2f} to match paper's 8 MP"))

    # ---- workload funnel (measured, end-to-end) -----------------------------
    from benchmarks.workloads import fa_cascade, fa_scan
    from repro.camera.motion import motion_mask
    from repro.camera.synthetic import security_video
    from repro.camera.viola_jones import detect_faces_batch
    if smoke:
        frames, truth = security_video(n_frames=10, motion_frames=5, seed=1)
        casc = fa_cascade(smoke=True)
    else:
        frames, truth = security_video()
        casc = fa_cascade(frames=frames, truth=truth)
    scan = fa_scan(smoke)
    mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
    mask = np.asarray(mask)

    def funnel(strictness):
        midx = np.where(mask)[0]
        dets_all, _stats = detect_faces_batch(
            casc, frames[midx], *scan, strictness=strictness)
        if _stats["dropped"]:
            # capacity overflow would silently shrink the funnel: redo with
            # the masked oracle (full capacities), frame at a time
            dets_all = [detect_faces_batch(casc, f, 1.25, 0.025, True,
                                           strictness=strictness,
                                           capacities=None)[0][0]
                        for f in frames[midx]]
        n_windows, missed = 0, 0
        for i, dets in zip(midx, dets_all):
            n_windows += len(dets)
            for (fy, fx, _s) in truth[i]["faces"]:
                hit = any(abs(dy - fy) < 12 and abs(dx - fx) < 12
                          for (dy, dx, _w) in dets)
                missed += 0 if hit else 1
        return n_windows, missed

    # deployment threshold: strictest setting that misses no true face
    best = (None, None, None)
    for strict in (0.0, 0.5, 1.0, 1.5, 2.0, 2.5):
        nw, ms = funnel(strict)
        if ms == 0:
            best = (strict, nw, ms)
        else:
            break
    strict, n_windows, missed = best if best[0] is not None else (0.0,) + funnel(0.0)
    out.append(("funnel", "frames_total", str(len(frames)), "paper: 62"))
    out.append(("funnel", "motion_passed", str(int(mask.sum())),
                "paper: 12 (extra = innocuous triggers, which the paper also reports)"))
    out.append(("funnel", "windows_to_nn", str(n_windows),
                f"paper: ~40; strictness={strict} — our from-scratch 10x33 "
                "cascade is weaker than the paper's production detector; the "
                "funnel SHAPE and the 0-missed invariant are the claims"))
    out.append(("funnel", "true_faces_missed", str(missed), "paper: 0"))
    out.append(("funnel", "window_reduction",
                f"{100*(1-n_windows/(int(mask.sum())*7900)):.1f}%",
                "vs scanning every window of every motion frame"))
    return out


def main():
    for row in rows():
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
