"""Shared §III benchmark workload constants (one definition, four users).

fa_system, vj_tradeoffs, detect_hotpath and fa_hotpath all exercise the
same detector on the same two operating points; keeping the toy-vs-full
cascade and scan constants here means a change to the smoke workload
cannot silently de-synchronize the sections the smoke CI probe compares.
"""

from __future__ import annotations

import numpy as np

# (scale_factor, step, adaptive)
SMOKE_SCAN = (1.6, 8.0, False)       # coarse: seconds-fast, offline
FULL_SCAN = (1.25, 0.025, True)      # the paper's §III-B pick


def fa_scan(smoke: bool = False) -> tuple:
    return SMOKE_SCAN if smoke else FULL_SCAN


def fa_cascade(smoke: bool = False, frames=None, truth=None):
    """Train the benchmark detector: a toy 2x6 cascade on 80/class
    (smoke) or the full Table-I 10x33 on 400/class, with hard negatives
    harvested from the security video when (frames, truth) are given."""
    from repro.camera.synthetic import face_dataset
    from repro.camera.viola_jones import (
        harvest_hard_negatives, make_feature_pool, train_cascade)

    if smoke:
        X, y, _ = face_dataset(n_per_class=80, seed=3)
        return train_cascade(X, y, make_feature_pool(n=60), n_stages=2,
                             per_stage=6, seed=0)
    X, y, _ = face_dataset(n_per_class=400, seed=3)
    if frames is not None:
        neg = harvest_hard_negatives(frames, truth)
        X = np.concatenate([X, neg])
        y = np.concatenate([y, np.zeros(len(neg), np.int32)])
    return train_cascade(X, y, make_feature_pool(n=250), n_stages=10,
                         per_stage=33, seed=0)


def host_loop_funnel(ex, frames, nn_fn, prepared=None):
    """The per-motion-frame host-loop funnel — the golden oracle the
    streaming executor is pinned against (benchmarks/fa_hotpath.py parity
    rows AND tests/test_camera_pipeline.py assert against this one
    implementation): motion mask on host, ``ex.det.detect`` over the
    motion frames, numpy ``extract_windows`` crops, ``nn_fn`` on the
    flattened crops, threshold count.

    Returns ``(mask, n_win, n_auth, scores, prepared)`` with per-frame
    int64 count arrays and ``scores[i]`` the per-window array for motion
    frame ``i``.  Pass the returned ``prepared`` (the detection + crop
    pass) back in to re-apply a different NN to identical crops.
    """
    from repro.camera.motion import motion_mask
    from repro.camera.viola_jones import extract_windows
    import jax.numpy as jnp

    mask, _ = motion_mask(jnp.asarray(frames), ex.motion_threshold,
                          ex.motion_factor)
    mask = np.asarray(mask)
    midx = np.where(mask)[0]
    if prepared is None:
        dets_all, _stats = ex.det.detect(frames[midx])
        crops = {}
        for i, dets in zip(midx, dets_all):
            if dets:
                wins = extract_windows(frames[i], dets)
                crops[i] = (len(dets), wins.reshape(len(wins), -1))
            else:
                crops[i] = (0, None)
        prepared = crops
    n_win = np.zeros(len(frames), np.int64)
    n_auth = np.zeros(len(frames), np.int64)
    scores = {}
    for i, (n, flat) in prepared.items():
        n_win[i] = n
        if not n:
            continue
        s = np.asarray(nn_fn(flat))
        scores[i] = s
        n_auth[i] = int((s > ex.auth_threshold).sum())
    return mask, n_win, n_auth, scores, prepared
