"""Offload runtime sweep: cut point x codec bit-width x duty cycle, with
MEASURED payload bytes (BENCH_offload.json).

The two paper findings, reproduced on live executors instead of the
analytic cost model:

  knee   — §III-A's 8-bit knee: sweeping the wire codec over 16/8/4 bits
           halves the measured wire bytes per step while the end metric
           (auth decisions / panorama error) is unchanged down to 8 bits
           and degrades past it.
  duty   — §V's "early data reduction dominates": across duty cycles, an
           early-cut + wire-codec configuration beats BOTH ship-raw-frames
           AND compute-everything-on-node on the regime objective (watts
           for §III on the backscatter link, fps for §IV on 25 GbE) —
           and the §III-D flip emerges: at high duty the in-camera NN
           wins, at low duty offloading it wins.
  ctl    — the measurement-driven controller: its solve_cut choice over
           measured Block descriptors must match the exhaustive measured
           optimum, and the analytic model's predicted ranking is audited
           against the measured one (pairwise concordance).
  cong   — shared-link congestion: N WISPCam streams contending for one
           backscatter reader, per-frame latency from measured traces.

§IV measurements are taken on a toy-resolution rig and extrapolated to
the 16-camera 4K operating point through the controller's linear
byte/time scaling (payload bytes and per-stage work are linear in pixels
at every cut); §III runs at native 176x144.
"""

from __future__ import annotations

import numpy as np

FA_CUTS = ("sensor", "motion", "vj", "nn")
VR_CUTS = ("capture", "depth", "stitch")


def _fa_rows(smoke: bool):
    import jax.numpy as jnp

    from benchmarks.fa_hotpath import _workload
    from repro.camera.offload import (
        BACKSCATTER,
        CutController,
        FaceAuthOffloadExecutor,
        simulate_shared_link,
    )
    from repro.camera.pipelines import (
        FAWorkloadStats,
        FaceAuthExecutor,
        calibrate_fa,
        fa_pipeline,
        fa_profiles,
    )

    out = []
    frames, casc, nn, scan = _workload(smoke)
    fj = jnp.asarray(frames)
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2], **scan)
    ex.calibrate(frames)
    base = ex(fj)
    n_motion = int(np.asarray(base.motion).sum())
    n_windows = int(np.asarray(base.n_windows).sum())
    stats = FAWorkloadStats(n_frames=len(frames), motion_frames=max(n_motion, 1),
                            windows_to_nn=max(n_windows, 1))
    cal = calibrate_fa(stats)
    profiles = fa_profiles()
    profiles["nn"] = cal.nn_profile()
    duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}
    import dataclasses

    link = dataclasses.replace(BACKSCATTER,
                               joules_per_byte=cal.rf_joules_per_byte)
    template = fa_pipeline(stats)

    # ---- cut x bits sweep (measured bytes + end-metric parity) -------------
    bits_sweep = (None, 8, 4) if smoke else (None, 16, 8, 4)
    execs: dict = {}
    byte_table: dict = {}
    for cut in FA_CUTS:
        for bits in bits_sweep + ((16, 4) if cut == "vj" and smoke else ()):
            if (cut, bits) in execs:
                continue
            off = FaceAuthOffloadExecutor(ex, cut, bits=bits)
            res, pay = off(fj)
            execs[(cut, bits)] = off
            wire_b = pay.nbytes() / len(frames)
            auth_delta = int(np.abs(np.asarray(base.n_auth)
                                    - np.asarray(res.n_auth)).sum())
            score_d = float(np.abs(np.asarray(base.scores)
                                   - np.asarray(res.scores)).max())
            byte_table[(cut, bits)] = (wire_b, auth_delta, score_d)
            out.append(("offload", f"fa_bytes[{cut},{bits or 'raw'}]",
                        f"{wire_b:.1f} B/frame",
                        f"auth_delta={auth_delta} score_maxd={score_d:.4f} "
                        f"capacity={pay.capacity_bytes()/len(frames):.0f}"))

    # ---- the 8-bit knee on the detected-window payload ---------------------
    knee_bits = [b for b in (16, 8, 4) if (("vj", b) in byte_table)]
    raw_b = byte_table[("vj", None)][0]
    knee = {b: byte_table[("vj", b)] for b in knee_bits}
    err8, err4 = knee[8][2], knee[4][2]
    d8, d4 = knee[8][1], knee[4][1]
    n_auth = max(int(np.asarray(base.n_auth).sum()), 1)
    out.append(("offload", "fa_knee_bytes",
                " ".join(f"{b}b={knee[b][0]:.0f}B" for b in knee_bits),
                f"raw(f32)={raw_b:.0f}B — bytes halve per step"))
    out.append(("offload", "fa_knee_error",
                " ".join(f"{b}b={knee[b][2]:.4f}" for b in knee_bits),
                "paper §III-A shape: ~flat to 8 bits, degrades at 4 "
                f"(auth_delta 8b={d8} 4b={d4} of {n_auth})"))
    # the paper's knee: 8-bit costs ~0.4% accuracy for 4x fewer bytes
    # than f32; 4-bit is past the knee (errors and flipped decisions jump)
    knee_ok = (d8 <= max(1, int(0.05 * n_auth)) and d4 >= d8
               and err4 > max(3 * err8, err8 + 0.005))
    out.append(("offload", "fa_knee_at_8bit", str(knee_ok),
                f"8-bit: <=1 flipped decision ({d8}/{n_auth}) at "
                f"{raw_b/knee[8][0]:.1f}x fewer bytes than f32"))

    # ---- duty-cycle sweep: the regime objective per cut (bits=8 codec) -----
    ctl = CutController(
        lambda cut: execs[(cut, 8)], cuts=FA_CUTS, template=template,
        profiles=profiles, link=link, regime="energy", unit_rate_hz=1.0,
        duties=duties)
    ctl.calibrate(fj)
    winners = {}
    for duty in (0.2, 1.0, 5.0):
        ctl.unit_rate_hz = duty
        rep = ctl.report()
        obj = rep.measured_objectives
        winners[duty] = rep.measured_best_cut
        order = sorted(obj, key=obj.get)
        early = min(obj["motion"], obj["vj"])
        beats = early < obj["sensor"] and early < obj["nn"]
        out.append(("offload", f"fa_duty[{duty}]_uW",
                    " ".join(f"{c}={obj[c]*1e6:.1f}" for c in FA_CUTS),
                    f"winner={order[0]} early_beats_raw_and_onnode={beats}"))
    ctl.unit_rate_hz = 1.0
    out.append(("offload", "fa_duty_flip",
                f"low={winners[0.2]} mid={winners[1.0]} high={winners[5.0]}",
                "paper §III-D: offload NN at low duty; in-camera NN pays "
                "once window traffic amortizes it"))

    # ---- controller: solve_cut on measured blocks vs measured optimum ------
    rep = ctl.report()
    out.append(("offload", "fa_controller_choice", rep.chosen_cut,
                f"measured_best={rep.measured_best_cut} agrees={rep.agrees}"))
    out.append(("offload", "fa_rank_agreement", f"{rep.rank_agreement:.2f}",
                "predicted (hand-entered descriptors) vs measured ranking"))
    mt = {m.cut: m for m in rep.measurements}
    out.append(("offload", "fa_measured_vs_analytic_bytes",
                " ".join(
                    f"{c}={mt[c].bytes_per_unit:.0f}/"
                    f"{template.cut_payload_bytes(template.index(c)):.0f}"
                    for c in FA_CUTS),
                "measured(8b codec) / analytic bytes_out per source frame"))

    # ---- shared-link congestion: a WISPCam fleet on one reader -------------
    # per-frame traces shaped by the measured funnel counts and rescaled so
    # each stream's total equals the MEASURED wire bytes of its cut
    n_streams = 4 if smoke else 8
    vj_shape = np.asarray(base.n_windows, np.float64) * 400.0 + 16.0
    vj_shape *= (byte_table[("vj", 8)][0] * len(frames)
                 / max(vj_shape.sum(), 1.0))
    per_frame = {
        "sensor": np.full(len(frames),
                          byte_table[("sensor", 8)][0], np.float64),
        "vj": vj_shape,
    }
    for cut in ("sensor", "vj"):
        trace = np.stack([np.roll(per_frame[cut], 3 * s)
                          for s in range(n_streams)])
        lrep = simulate_shared_link(trace, link, frame_period_s=1.0)
        out.append(("offload", f"fa_congestion[{cut},{n_streams}str]",
                    f"p99={lrep.p99_latency_s:.2f}s "
                    f"util={lrep.utilization:.2f}",
                    f"mean={lrep.mean_latency_s:.2f}s "
                    f"J/frame={lrep.joules/trace.size:.2e} "
                    f"ontime@1s={lrep.realtime_fraction(1.0):.2f}"))
    return out, (knee, rep)


def _vr_rows(smoke: bool):
    import jax.numpy as jnp

    from repro.camera.bssa import GridSpec
    from repro.camera.offload import (
        ETH_25G_LINK,
        ETH_400G_LINK,
        CutController,
        VROffloadExecutor,
    )
    from repro.camera.pipelines import (
        VR_CAMS,
        VR_H,
        VR_W,
        VRRigExecutor,
        VRWorkloadStats,
        vr_pipeline,
        vr_profiles,
    )
    from repro.camera.synthetic import stereo_pair
    from repro.core.costmodel import VIRTEX_FPGA

    out = []
    if smoke:
        n_pairs, h, w, max_disp, n_iters = 2, 48, 64, 4, 2
    else:
        n_pairs, h, w, max_disp, n_iters = 4, 128, 192, 8, 4
    views = [stereo_pair(h=h, w=w, max_disp=max_disp, seed=2 + s)[:2]
             for s in range(n_pairs)]
    lefts = jnp.stack([v[0] for v in views])
    rights = jnp.stack([v[1] for v in views])
    base = VRRigExecutor(GridSpec(sigma_spatial=8), max_disp=max_disp,
                         n_iters=n_iters, rig_parallel=False)
    lp0, rp0, _d0 = base(lefts, rights)

    # toy rig -> 16-camera 4K rig extrapolation (linear in pixels)
    scale = (VR_CAMS * VR_H * VR_W) / (2 * n_pairs * h * w)

    bits_sweep = (None, 8, 4) if smoke else (None, 16, 8, 4)
    execs: dict = {}
    byte_table: dict = {}
    for cut in VR_CUTS:
        for bits in bits_sweep:
            off = VROffloadExecutor(base, cut, bits=bits)
            (lp, rp), pay = off(lefts, rights)
            execs[(cut, bits)] = off
            pano_d = float(jnp.abs(lp - lp0).max())
            byte_table[(cut, bits)] = (pay.nbytes(), pano_d)
            out.append(("offload", f"vr_bytes[{cut},{bits or 'raw'}]",
                        f"{pay.nbytes()*scale/1e6:.1f} MB/rig-frame@4K",
                        f"toy={pay.nbytes():.0f}B pano_maxd={pano_d:.4f}"))

    knee = {b: byte_table[("capture", b)] for b in bits_sweep if b}
    out.append(("offload", "vr_knee_error",
                " ".join(f"{b}b={knee[b][1]:.4f}" for b in knee),
                f"raw={byte_table[('capture', None)][1]:.4f} — the 8-bit "
                "point costs <1% panorama error, 4-bit is past the knee"))

    # ---- throughput objective at the native operating point ----------------
    stats = VRWorkloadStats()
    template = vr_pipeline(stats)
    profiles = vr_profiles(VIRTEX_FPGA)
    ctl = CutController(
        lambda cut: execs[(cut, 8)], cuts=VR_CUTS, template=template,
        profiles=profiles, link=ETH_25G_LINK, regime="throughput",
        byte_scale=scale, time_scale=scale)
    ctl.calibrate(lefts, rights, units=1)
    rep = ctl.report()
    obj = {c: -v for c, v in rep.measured_objectives.items()}   # fps
    out.append(("offload", "vr_fps_25GbE_8bit",
                " ".join(f"{c}={obj[c]:.1f}" for c in VR_CUTS),
                "measured toy rig extrapolated to 16x4K on 25 GbE"))

    # raw-f32 ship vs early-cut + codec vs full on-node, same scale.
    # Node compute per config = measured stage-time delta beyond the
    # capture baseline (transfer + codec + dispatch are common to every
    # config and cancel), extrapolated linearly to the 4K rig — the same
    # fit the controller uses; comm from measured bytes on native 25 GbE.
    node0 = [m for m in ctl.measurements if m.cut == "capture"][0].node_s

    def fps_of(cut, bits):
        m = [x for x in ctl.measurements if x.cut == cut][0]
        comm_fps = ETH_25G_LINK.bytes_per_s / (byte_table[(cut, bits)][0]
                                               * scale)
        stage_s = max(m.node_s - node0, 0.0) * scale
        node_fps = 1.0 / stage_s if stage_s > 0 else float("inf")
        return min(comm_fps, node_fps)

    raw_fps = fps_of("capture", None)
    early8_fps = fps_of("capture", 8)
    early4_fps = fps_of("capture", 4)
    onnode_fps = fps_of("stitch", 8)
    beats = early8_fps > raw_fps and early8_fps > onnode_fps
    out.append(("offload", "vr_early_reduction",
                f"raw={raw_fps:.1f} early+8b={early8_fps:.1f} "
                f"early+4b={early4_fps:.1f} onnode={onnode_fps:.1f} fps",
                f"early-cut+8b codec beats both: {beats} "
                "(paper: ship-raw dies on the 25 GbE link, all-on-node "
                "dies on this node class's depth compute; the codec'd "
                "early cut is the best placement)"))
    flip_fps = ETH_400G_LINK.bytes_per_s / (byte_table[("capture", 8)][0]
                                            * scale)
    out.append(("offload", "vr_400GbE_flip", f"{flip_fps:.0f} fps",
                "paper §IV-C: at 400 GbE raw offload clears real time "
                "again — the tradeoff inverts with the link"))
    out.append(("offload", "vr_controller_choice", rep.chosen_cut,
                f"measured_best={rep.measured_best_cut} agrees={rep.agrees} "
                f"rank_agreement={rep.rank_agreement:.2f}"))
    return out, rep


def rows(smoke: bool = False):
    fa, _fa_extra = _fa_rows(smoke)
    vr, _vr_extra = _vr_rows(smoke)
    return fa + vr


def main():
    import sys

    for row in rows(smoke="--smoke" in sys.argv):
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
