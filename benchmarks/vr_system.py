"""Paper §IV reproduction: real-time VR video pipeline (Fig. 13/14, Table II).

  fig13  — per-block compute share + output bytes (depth dominates both)
  fig14  — FPS ladder: {CPU, GPU, FPGA} x cut points on 25 GbE; only the
           full in-camera pipeline with FPGA BSSA clears 30 FPS
  x10    — FPGA vs CPU/GPU speedup on the depth block (paper: up to 10x)
  net    — 400 GbE flip: raw 16-camera feed uploads at ~395 FPS
  table2 — DSP-unit scaling argument (12 -> 682 compute units)
  vr_depth — with ``measured=True`` (the CLI and the ``vr`` benchmark
           section): the fused VRRigExecutor hot path measured against the
           seed jnp oracle (benchmarks/vr_depth_hotpath) — the x10 claim
           as wall clock, not just cost model
"""

from __future__ import annotations

import math

from repro.camera.pipelines import (
    VR_CAMS,
    VR_FPS_TARGET,
    VRWorkloadStats,
    vr_pipeline,
    vr_profiles,
)
from repro.core.costmodel import (
    ARM_A9,
    ETH_25G,
    ETH_400G,
    QUADRO_GPU,
    VIRTEX_FPGA,
    ZYNQ_FPGA,
    throughput_cost,
)
from repro.core.placement import solve_cut


def rows(measured: bool = False, smoke: bool = False):
    out = []
    stats = VRWorkloadStats()
    pipe = vr_pipeline(stats)

    # ---- Fig. 13: compute distribution & data sizes -------------------------
    profiles_cpu = vr_profiles(ARM_A9)
    total_t = 0.0
    times = {}
    for blk in pipe.effective_blocks():
        prof = profiles_cpu[blk.name]
        t = prof.time_for(blk) if (prof.flops_per_s or prof.mem_bw) else 0.0
        times[blk.name] = t
        total_t += t
    for blk in pipe.effective_blocks():
        out.append(("fig13", blk.name,
                    f"{100*times[blk.name]/total_t:.1f}% compute",
                    f"out={blk.bytes_out/1e6:.1f} MB"))
    dom = max(times, key=times.get)
    out.append(("fig13", "dominant_block", dom, "paper: depth (BSSA)"))

    # ---- Fig. 14: configuration ladder --------------------------------------
    # 8 camera pairs run in parallel FPGAs; per-pair pipeline must clear
    # 30 FPS and the uplink must carry 8x the cut payload.
    def fps_of(depth_dev, cut, link):
        profs = vr_profiles(depth_dev)
        rep = throughput_cost(pipe, profs, link, cut)
        comm_fps = link.link_bw / (8 * pipe.cut_payload_bytes(pipe.index(cut)))
        return min(rep.compute_fps, comm_fps), rep.compute_fps, comm_fps

    ladder = [
        ("offload_raw", ARM_A9, "capture"),
        ("offload_after_isp", ARM_A9, "isp"),
        ("offload_after_grid", ARM_A9, "grid"),
        ("cpu_depth_full", ARM_A9, "stitch"),
        ("gpu_depth_full", QUADRO_GPU, "stitch"),
        ("fpga_eval_zynq_full", ZYNQ_FPGA, "stitch"),
        ("fpga_target_virtex_full", VIRTEX_FPGA, "stitch"),
    ]
    passing = []
    for name, dev, cut in ladder:
        fps, cfps, mfps = fps_of(dev, cut, ETH_25G)
        ok = fps >= VR_FPS_TARGET
        if ok:
            passing.append(name)
        out.append(("fig14", name, f"{fps:.1f} fps",
                    f"compute={cfps:.1f} comm={mfps:.1f} {'PASS' if ok else 'fail'}"))
    out.append(("fig14", "only_passing_config",
                ",".join(passing) or "none",
                "paper: full pipeline + FPGA only"))

    # ---- 10x FPGA claim ------------------------------------------------------
    depth_blk = pipe.block("depth")
    eff_depth = [b for b in pipe.effective_blocks() if b.name == "depth"][0]
    t_cpu = ARM_A9.time_for(eff_depth)
    t_gpu = QUADRO_GPU.time_for(eff_depth)
    t_fpga = ZYNQ_FPGA.time_for(eff_depth)
    out.append(("x10", "fpga_vs_cpu", f"{t_cpu/t_fpga:.1f}x", "paper: up to 10x"))
    out.append(("x10", "fpga_vs_gpu", f"{t_gpu/t_fpga:.2f}x", ""))

    # ---- 400 GbE flip --------------------------------------------------------
    raw_16cam = 16 * (pipe.cut_payload_bytes(0) / 2)   # per-camera raw bytes
    fps_400 = ETH_400G.link_bw / raw_16cam
    out.append(("net", "raw_16cam_at_400GbE", f"{fps_400:.0f} fps",
                "paper: 395 fps -> offload right off the sensor wins again"))
    fps_25 = ETH_25G.link_bw / raw_16cam
    out.append(("net", "raw_16cam_at_25GbE", f"{fps_25:.1f} fps",
                "below 30 -> must process in-camera"))

    # ---- Table II: compute-unit scaling --------------------------------------
    units_needed = math.ceil(
        (eff_depth.flops * VR_FPS_TARGET) / (2 * 125e6))
    out.append(("table2", "dsp_units_for_realtime", str(units_needed),
                "zynq has 12; virtex-us+ has 682 (paper projection)"))
    t_virtex = VIRTEX_FPGA.time_for(eff_depth)
    out.append(("table2", "virtex_fps_on_depth", f"{1/t_virtex:.0f} fps", ""))

    # ---- solver agrees -------------------------------------------------------
    sol = solve_cut(pipe, vr_profiles(VIRTEX_FPGA), ETH_25G, regime="throughput")
    out.append(("fig14", "solver_pick", sol.report.config_name,
                f"{sol.report.fps:.1f} fps"))

    # ---- measured fused executor (the x10 claim as wall clock) ---------------
    if measured:
        from benchmarks import vr_depth_hotpath
        out.extend(vr_depth_hotpath.rows(smoke=smoke))
    return out


def main():
    for row in rows(measured=True):
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
