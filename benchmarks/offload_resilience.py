"""Fault-injected offload sweep: loss rate x outage duty (BENCH_resilience).

Everything BENCH_offload cannot say because PR 5 assumed a lossless link
and uninterrupted power:

  pin    — zero-fault OffloadSession output is bit-exact with the PR-5
           split executor at every cut x bits, and a fault sweep under a
           fixed seed reproduces row-for-row (the determinism acceptance:
           the same BENCH_resilience.json twice).
  sweep  — Gilbert-Elliott loss rate x outage duty on BACKSCATTER:
           flipped-auth fraction vs fault-free, retransmit-byte overhead,
           energy ratio, delivery/fallback fractions under the
           degradation ladder.
  brown  — harvested-energy brownouts: recovery latency and commit-point
           resume (node restores mid-funnel state instead of recomputing
           from capture), with the recovered result still exact.
  cong   — congested retries: a faulty neighbor's retransmissions queue
           against clean streams on the shared uplink; p99 clean vs
           congested from the re-entered link simulator.

All values are simulated-time/byte quantities — no wall clocks in the
rows, so the JSON is reproducible bit-for-bit under the fixed seeds.
"""

from __future__ import annotations

import dataclasses

import numpy as np

_SEED = 4321


def _fa(smoke: bool):
    import jax.numpy as jnp

    from benchmarks.fa_hotpath import _workload
    from repro.camera.offload import FaceAuthOffloadExecutor
    from repro.camera.pipelines import FaceAuthExecutor

    frames, casc, nn, scan = _workload(smoke)
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2], **scan)
    ex.calibrate(frames)
    fj = jnp.asarray(frames)
    offs = {bits: FaceAuthOffloadExecutor(ex, "nn", bits=bits)
            for bits in (16, 8, 4)}
    return ex, fj, offs


def _run_cell(ex, fj, offs, injector, n_sends, ladder_rungs):
    """One sweep cell: a laddered session under ``injector``."""
    from repro.camera.offload import DegradationLadder, OffloadSession
    from repro.camera.offload.link import BACKSCATTER

    sess = OffloadSession(
        make_executor=lambda cut, bits: offs[bits], cut="nn", bits=16,
        link=BACKSCATTER, injector=injector,
        ladder=DegradationLadder(list(ladder_rungs)),
        on_node_fn=lambda f: ex(f))
    auths = []
    for _ in range(n_sends):
        got, _rec = sess.send(fj)
        auths.append(None if got is None else np.asarray(got.auth))
    return sess, auths


def rows(smoke: bool = False):
    import jax.numpy as jnp

    from repro.camera.offload import (
        BACKSCATTER,
        BrownoutModel,
        FaultInjector,
        GilbertElliott,
        ON_NODE,
        OffloadSession,
        fleet_link_report,
    )

    out = []
    ex, fj, offs = _fa(smoke)
    n_sends = 16 if smoke else 40
    rungs = [("nn", 16), ("nn", 8), ("nn", 4), ON_NODE]

    # ---- pin: zero-fault bit-exactness at every cut x bits -----------------
    from repro.camera.offload import FaceAuthOffloadExecutor

    pin_bits = (None, 8) if smoke else (None, 16, 8, 4)
    fields = ("motion", "n_windows", "n_auth", "scores", "window_id",
              "window_valid", "auth", "windows_dropped", "motion_dropped",
              "cascade_dropped")
    exact = True
    for cut in FaceAuthOffloadExecutor.CUTS:
        for bits in pin_bits:
            off = FaceAuthOffloadExecutor(ex, cut, bits=bits)
            want, _ = off(fj)
            got, _rec = OffloadSession(off, link=BACKSCATTER).send(fj)
            exact &= all(
                bool(np.array_equal(np.asarray(getattr(want, f)),
                                    np.asarray(getattr(got, f))))
                for f in fields)
    out.append(("resilience", "zero_fault_bitexact", int(exact),
                f"session == PR5 executor, {len(FaceAuthOffloadExecutor.CUTS)}"
                f" cuts x {len(pin_bits)} bit widths"))

    # ---- pin: fixed-seed determinism (same JSON twice) ---------------------
    det_inj = FaultInjector(loss=GilbertElliott(p_gb=0.2, p_bg=0.4),
                            corrupt_fraction=0.3, seed=_SEED)
    runs = []
    for _ in range(2):
        det_inj.reset()
        sess, _ = _run_cell(ex, fj, offs, det_inj, max(n_sends // 2, 4),
                            rungs)
        runs.append([dataclasses.astuple(r) for r in sess.records])
    out.append(("resilience", "determinism", int(runs[0] == runs[1]),
                "identical delivery records across two seeded sweeps"))

    # ---- fault-free baseline for the sweep ---------------------------------
    base_sess, base_auth = _run_cell(ex, fj, offs, None, n_sends, rungs)
    base_energy = base_sess.energy_j
    out.append(("resilience", "baseline_energy_j", f"{base_energy:.6g}",
                f"fault-free laddered session, {n_sends} sends at (nn,16)"))

    # ---- sweep: loss rate x outage duty ------------------------------------
    # The §15 per-stream SLO ledger shadows the sweep: every delivered
    # send's auth decisions are attributed to the rung that served it
    # (from the cell's own DeliveryRecords), with the fault-free run as
    # the pinned reference — the ledger's rung-attributed flip counts
    # must reproduce the sweep's flip numbers within 1 flipped unit.
    from repro.obs import SLOLedger

    ledger = SLOLedger()
    ledger_match = True
    max_flip_diff = 0
    loss_rates = (0.05, 0.1) if smoke else (0.02, 0.05, 0.1, 0.2)
    duties = (0.0, 0.2) if smoke else (0.0, 0.1, 0.2)
    for loss in loss_rates:
        # stationary loss = p_gb/(p_gb+p_bg); hold mean burst ~2.2 attempts
        p_bg = 0.45
        p_gb = loss * p_bg / (1.0 - loss)
        for duty in duties:
            # per-cell seed (still fixed) so cells sample distinct burst
            # phases instead of replaying one lucky/unlucky trajectory
            inj = FaultInjector(
                loss=GilbertElliott(p_gb=p_gb, p_bg=p_bg),
                outage_period_s=60.0 if duty else None, outage_duty=duty,
                seed=_SEED + int(loss * 1000) + int(duty * 10))
            sess, auths = _run_cell(ex, fj, offs, inj, n_sends, rungs)
            delivered = [a is not None for a in auths]
            flips = [float(np.mean(a != b))
                     for a, b in zip(auths, base_auth) if a is not None]
            retx = sum(r.attempts - 1 for r in sess.records)
            att = sum(r.attempts for r in sess.records)
            tag = f"loss{int(loss * 100):02d}_duty{int(duty * 100):02d}"
            cell_flip_units = 0
            for a, b, rec in zip(auths, base_auth, sess.records):
                rung = "on_node" if rec.fallback else (rec.cut, rec.bits)
                ledger.observe_latency(tag, rung, rec.latency_s)
                if a is None:
                    continue
                ledger.observe_auth(tag, rung, a, b)
                cell_flip_units += int(np.sum(a != b))
            led_flipped, _led_total = ledger.flip_counts(sid=tag)
            max_flip_diff = max(max_flip_diff,
                                abs(led_flipped - cell_flip_units))
            ledger_match &= abs(led_flipped - cell_flip_units) <= 1
            out.append(("resilience", f"{tag}_flip",
                        f"{float(np.mean(flips)) if flips else 1.0:.4f}",
                        "flipped-auth fraction vs fault-free"))
            out.append(("resilience", f"{tag}_retx_overhead",
                        f"{retx / max(att - retx, 1):.4f}",
                        "retransmitted / first-attempt transmissions"))
            out.append(("resilience", f"{tag}_energy_ratio",
                        f"{sess.energy_j / base_energy:.4f}",
                        "session energy vs fault-free"))
            out.append(("resilience", f"{tag}_delivered",
                        f"{float(np.mean(delivered)):.4f}",
                        f"delivery fraction over {n_sends} sends "
                        f"(rung ends {sess.ladder.rung})"))

    # ---- ledger: rung-attributed accuracy SLO ------------------------------
    rung_flips = {}
    for row in ledger.report():
        f, n = rung_flips.get(row["rung"], (0, 0))
        rung_flips[row["rung"]] = (f + row["flipped"], n + row["compared"])
    for rk in sorted(rung_flips):
        f, n = rung_flips[rk]
        out.append(("resilience", f"ledger_flip[{rk}]",
                    f"{f / n if n else 0.0:.4f}",
                    f"rung-attributed auth-flip rate ({f}/{n} units) "
                    "from the per-stream SLO ledger"))
    out.append(("resilience", "ledger_flip_match", int(ledger_match),
                f"ledger rung-attributed flip counts vs sweep flip "
                f"counts, max |diff|={max_flip_diff} (acceptance <= 1)"))
    assert ledger_match, \
        "SLO ledger flip attribution diverged from the sweep (> 1 flip)"

    # ---- brownout recovery --------------------------------------------------
    import tempfile

    bo = BrownoutModel(harvest_w=15e-6, storage_j=13e-6, load_w=200e-6,
                       jitter=0.2)
    binj = FaultInjector(brownout=bo, seed=_SEED)
    off8 = offs[8]
    want, _ = off8(fj)
    with tempfile.TemporaryDirectory() as td:
        bsess = OffloadSession(off8, link=BACKSCATTER, injector=binj,
                               ckpt_dir=td, stage_cost_s=0.02)
        n_b = 4 if smoke else 10
        resumed_exact = True
        for _ in range(n_b):
            got, _rec = bsess.send(fj)
            resumed_exact &= all(
                bool(np.array_equal(np.asarray(getattr(want, f)),
                                    np.asarray(getattr(got, f))))
                for f in fields)
        recs = bsess.records
        out.append(("resilience", "brownout_resume_exact", int(resumed_exact),
                    "commit-point recovery output == fused split executor"))
        out.append(("resilience", "brownouts_total",
                    sum(r.brownouts for r in recs),
                    f"node power losses across {n_b} sends "
                    f"(restores {sum(r.restores for r in recs)})"))
        out.append(("resilience", "recovery_latency_s",
                    f"{float(np.mean([r.recovery_s for r in recs])):.4f}",
                    "mean dark+restore seconds per send (simulated)"))
        prefix_once = all(bsess.stage_completed[s] <= n_b
                          for s in ("motion", "detect", "gather"))
        out.append(("resilience", "resume_not_recompute", int(prefix_once),
                    "upstream stages never re-ran after a brownout"))

    # ---- congestion: retries queue against neighbors ------------------------
    def fleet(faulty):
        sessions = []
        for s in range(3):
            inj = (FaultInjector(loss=GilbertElliott(p_gb=0.5, p_bg=0.3),
                                 seed=_SEED + s) if faulty and s == 0
                   else None)
            fs = OffloadSession(off8, link=BACKSCATTER, injector=inj)
            for _ in range(4 if smoke else 12):
                fs.send(fj)
            sessions.append(fs)
        # globally-triggered rig (stagger=False): all three streams key up
        # each frame slot, so stream 0's retries queue its neighbors
        return fleet_link_report(sessions, BACKSCATTER, frame_period_s=1.0,
                                 stagger=False)

    clean, cong = fleet(False), fleet(True)
    out.append(("resilience", "p99_clean_s", f"{clean.p99_latency_s:.4f}",
                "3 clean streams sharing BACKSCATTER"))
    out.append(("resilience", "p99_congested_s", f"{cong.p99_latency_s:.4f}",
                "stream 0 faulty: its retries delay streams 1-2"))
    out.append(("resilience", "congestion_bytes_overhead",
                f"{cong.bytes_total / clean.bytes_total:.4f}",
                "on-air bytes vs clean fleet"))
    return out


if __name__ == "__main__":
    for row in rows(smoke=True):
        print(",".join(str(c) for c in row))
