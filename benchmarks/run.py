"""Benchmark orchestrator — one section per paper table/figure + the
assignment's roofline report.  Prints ``table,name,value,note`` CSV rows
and wall time per section.

    PYTHONPATH=src python -m benchmarks.run \
        [--only fa,vr,vj,nn,bssa,roofline,detect,fa_hotpath,offload,resilience] \
        [--json OUT_DIR] [--smoke]

``--json OUT_DIR`` additionally writes each section's rows plus wall time
to ``OUT_DIR/BENCH_<section>.json`` — the machine-readable perf
trajectory (BENCH_detect.json carries the fused-front-end speedup,
BENCH_vr.json the fused VR depth-executor speedup, BENCH_fa_hotpath.json
the §III streaming-executor speedup).  Every file shares the ``bench.v1``
top-level schema (``repro.obs.bench.bench_record``) so any two runs are
machine-diffable: ``python -m repro.obs diff BENCH_a.json BENCH_b.json``.

``--smoke`` runs EVERY section at toy sizes, fully offline and on a few
seconds' budget each — the CI probe (tests/test_bench_smoke.py) that
keeps benchmark code from bit-rotting between releases.  Smoke rows are
for liveness, not for quoting numbers.
"""

import argparse
import os
import time

from repro.obs.bench import bench_record, write_bench

SECTIONS = {}


def section(name):
    def deco(fn):
        SECTIONS[name] = fn
        return fn
    return deco


@section("fa")
def _fa(smoke=False):
    from benchmarks import fa_system
    return fa_system.rows(smoke=smoke)


@section("vr")
def _vr(smoke=False):
    # cost-model rows + the measured fused-vs-oracle depth hot path
    # (BENCH_vr.json carries the §IV speedup acceptance)
    from benchmarks import vr_system
    return vr_system.rows(measured=True, smoke=smoke)


@section("vj")
def _vj(smoke=False):
    from benchmarks import vj_tradeoffs
    return vj_tradeoffs.rows(smoke=smoke)


@section("nn")
def _nn(smoke=False):
    from benchmarks import face_nn_tradeoffs
    return face_nn_tradeoffs.rows(smoke=smoke)


@section("bssa")
def _bssa(smoke=False):
    from benchmarks import bssa_quality
    return bssa_quality.rows(smoke=smoke)


@section("detect")
def _detect(smoke=False):
    from benchmarks import detect_hotpath
    return detect_hotpath.rows(smoke=smoke)


@section("fa_hotpath")
def _fa_hotpath(smoke=False):
    from benchmarks import fa_hotpath
    return fa_hotpath.rows(smoke=smoke)


@section("offload")
def _offload(smoke=False):
    # cut x codec-bit-width x duty sweep over MEASURED payload bytes
    # (BENCH_offload.json carries the 8-bit knee + early-reduction-wins
    # acceptance and the controller-vs-measured-optimum agreement)
    from benchmarks import offload_tradeoffs
    return offload_tradeoffs.rows(smoke=smoke)


@section("resilience")
def _resilience(smoke=False):
    # fault-injected offload: loss rate x outage duty on BACKSCATTER
    # (BENCH_resilience.json carries the zero-fault bit-exact pin, the
    # fixed-seed determinism row, retransmit/energy overhead per cell,
    # brownout commit-point recovery, and congested-retry fleet p99)
    from benchmarks import offload_resilience
    return offload_resilience.rows(smoke=smoke)


@section("serving")
def _serving(smoke=False):
    # fleet-scale streaming runtime: quiet fleet + hot wave on a shared
    # uplink (BENCH_serving.json carries sustained streams, p99 dispatch
    # latency vs SLO, congestion-driven cut changes, and the single-stream
    # bit-identity rows; rows() itself asserts the pins)
    from benchmarks import serving
    return serving.rows(smoke=smoke)


@section("serving_chaos")
def _serving_chaos(smoke=False):
    # chaos-hardened fleet: GE loss x device-kill x brownout sweep over
    # one StreamingServer in an 8-device child (BENCH_serving_chaos.json
    # carries the zero-fault bit-identity pin, exactly-once frame
    # accounting per cell, the DRR starvation bound, post-recovery p99
    # vs SLO, and the mid-drive server checkpoint/restore row; rows()
    # itself asserts the pins)
    from benchmarks import serving_chaos
    return serving_chaos.rows(smoke=smoke)


@section("analysis")
def _analysis(smoke=False):
    # static contract gate (BENCH_analysis.json carries the non_baselined
    # count — the same 0 the tier-1 gate test enforces)
    from benchmarks import analysis_gate
    return analysis_gate.rows(smoke=smoke)


@section("roofline")
def _roofline(smoke=False):
    from benchmarks import roofline
    roofline.main(smoke=smoke)
    return [("roofline", "table", "printed above", "see EXPERIMENTS.md")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="directory to write BENCH_<section>.json files")
    ap.add_argument("--smoke", action="store_true",
                    help="every section at toy sizes (CI liveness probe)")
    args = ap.parse_args()
    names = list(SECTIONS) if args.only == "all" else args.only.split(",")
    for name in names:
        t0 = time.time()
        print(f"\n===== {name} =====")
        try:
            rows = SECTIONS[name](smoke=args.smoke)
            for row in rows:
                print(",".join(str(c) for c in row))
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name},ERROR,{type(e).__name__},{e}")
            raise
        wall = time.time() - t0
        print(f"# {name}: {wall:.1f}s")
        if args.json:
            os.makedirs(args.json, exist_ok=True)
            path = os.path.join(args.json, f"BENCH_{name}.json")
            # one shared top-level schema (bench.v1) for every section so
            # BENCH_*.json files are machine-diffable: repro.obs bench-diff
            # keys rows by (tag, metric) and ignores wall/timestamps
            write_bench(path, bench_record(name, rows, wall,
                                           smoke=bool(args.smoke)))
            print(f"# wrote {path}")


if __name__ == "__main__":
    main()
