"""Benchmark orchestrator — one section per paper table/figure + the
assignment's roofline report.  Prints ``table,name,value,note`` CSV rows
and wall time per section.

    PYTHONPATH=src python -m benchmarks.run \
        [--only fa,vr,vj,nn,bssa,roofline,detect] [--json OUT_DIR]

``--json OUT_DIR`` additionally writes each section's rows plus wall time
to ``OUT_DIR/BENCH_<section>.json`` — the machine-readable perf
trajectory (BENCH_detect.json carries the fused-front-end speedup,
BENCH_vr.json the fused VR depth-executor speedup).
"""

import argparse
import json
import os
import time


SECTIONS = {}


def section(name):
    def deco(fn):
        SECTIONS[name] = fn
        return fn
    return deco


@section("fa")
def _fa():
    from benchmarks import fa_system
    return fa_system.rows()


@section("vr")
def _vr():
    # cost-model rows + the measured fused-vs-oracle depth hot path
    # (BENCH_vr.json carries the §IV speedup acceptance)
    from benchmarks import vr_system
    return vr_system.rows(measured=True)


@section("vj")
def _vj():
    from benchmarks import vj_tradeoffs
    return vj_tradeoffs.rows()


@section("nn")
def _nn():
    from benchmarks import face_nn_tradeoffs
    return face_nn_tradeoffs.rows()


@section("bssa")
def _bssa():
    from benchmarks import bssa_quality
    return bssa_quality.rows()


@section("detect")
def _detect():
    from benchmarks import detect_hotpath
    return detect_hotpath.rows()


@section("roofline")
def _roofline():
    from benchmarks import roofline
    roofline.main()
    return [("roofline", "table", "printed above", "see EXPERIMENTS.md")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="directory to write BENCH_<section>.json files")
    args = ap.parse_args()
    names = list(SECTIONS) if args.only == "all" else args.only.split(",")
    for name in names:
        t0 = time.time()
        print(f"\n===== {name} =====")
        try:
            rows = SECTIONS[name]()
            for row in rows:
                print(",".join(str(c) for c in row))
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name},ERROR,{type(e).__name__},{e}")
            raise
        wall = time.time() - t0
        print(f"# {name}: {wall:.1f}s")
        if args.json:
            os.makedirs(args.json, exist_ok=True)
            path = os.path.join(args.json, f"BENCH_{name}.json")
            with open(path, "w") as fh:
                json.dump({"section": name, "wall_s": wall,
                           "rows": [[str(c) for c in row] for row in rows]},
                          fh, indent=1)
            print(f"# wrote {path}")


if __name__ == "__main__":
    main()
