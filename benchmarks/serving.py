"""Fleet-scale streaming serving benchmark (DESIGN.md §13 acceptance).

Drives :class:`repro.camera.serve.StreamingServer` through a two-phase
sweep — a quiet fleet, then a wave of hot (motion-heavy) streams that
overloads the shared backscatter uplink — and reports:

* sustained stream count + measured p99 micro-batch dispatch latency
  against the configured SLO,
* per-stream cut adaptation as ``simulate_shared_link`` congestion rises
  (the windowed ``CutController.resolve_window`` deadline constraint),
* single-stream bit-identity against the fused ``FaceAuthExecutor`` at
  the same cut/bits (the serving runtime adds scheduling, never math).

``--smoke`` serves a toy fleet in seconds and asserts the two CI pins
(p99 <= SLO, at least one windowed re-solve fired); the full run serves
>= 1k simulated WISPCam streams.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _setup(smoke: bool):
    """Executor + calibrated controller + video pools at 96x176.

    Serving measures scheduling (p99, bytes, cut churn), not detection
    quality, so both modes train the toy detector; the full run scales the
    *fleet*, not the model.
    """
    import jax.numpy as jnp

    from benchmarks.workloads import fa_cascade, fa_scan
    from repro.camera.face_nn import train_face_nn
    from repro.camera.offload import BACKSCATTER, CutController
    from repro.camera.offload.executors import FaceAuthOffloadExecutor
    from repro.camera.pipelines import (FAWorkloadStats, FaceAuthExecutor,
                                        calibrate_fa, fa_pipeline,
                                        fa_profiles)
    from repro.camera.serve import FA_CUTS
    from repro.camera.synthetic import face_dataset, security_video

    h, w = 96, 176        # reduced WISPCam frame (generator floor: 91x160)
    quiet = [security_video(n_frames=32, h=h, w=w, motion_frames=1,
                            seed=11 + k)[0] for k in range(4)]
    hot = [security_video(n_frames=24, h=h, w=w, motion_frames=20,
                          seed=31 + k)[0] for k in range(2)]
    calib, _ = security_video(n_frames=12, h=h, w=w, motion_frames=5, seed=1)

    casc = fa_cascade(smoke=True)
    X, y, _ = face_dataset(n_per_class=80, seed=3)
    nn = train_face_nn(X, y, steps=60)
    sf, st, ad = fa_scan(smoke=True)
    ex = FaceAuthExecutor(casc, nn, h, w, scale_factor=sf, step=st,
                          adaptive=ad)
    ex.calibrate(calib)

    fj = jnp.asarray(calib)
    base = ex(fj)
    stats = FAWorkloadStats(
        n_frames=len(calib),
        motion_frames=max(int(np.asarray(base.motion).sum()), 1),
        windows_to_nn=max(int(np.asarray(base.n_windows).sum()), 1))
    cal = calibrate_fa(stats)
    profiles = fa_profiles()
    profiles["nn"] = cal.nn_profile()
    link = dataclasses.replace(BACKSCATTER,
                               joules_per_byte=cal.rf_joules_per_byte)
    ctl = CutController(
        lambda cut: FaceAuthOffloadExecutor(ex, cut, bits=8,
                                            use_pallas=False),
        cuts=FA_CUTS, template=fa_pipeline(stats), profiles=profiles,
        link=link, regime="energy", unit_rate_hz=1.0,
        duties={"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0})
    ctl.calibrate(fj)
    return ex, ctl, quiet, hot, calib


def _mean_chunk_bytes(ex, videos, cut, bits, chunk):
    """Measured mean wire bytes per chunk for a video pool at one cut."""
    import jax.numpy as jnp

    from repro.camera.offload.executors import FaceAuthOffloadExecutor

    off = FaceAuthOffloadExecutor(ex, cut, bits=bits, use_pallas=False)
    vals = []
    for v in videos:
        for s in range(0, len(v) - chunk + 1, chunk):
            _, wb = off._node_fn(jnp.asarray(v[s:s + chunk]), *off._consts)
            vals.append(float(wb))
    return float(np.mean(vals))


def _drive(srv, specs, ticks, t0):
    """Tick the server; ``specs[sid] = (video, offset, frames_per_tick)``
    feeds the queues, each stream phase-shifted into its video."""
    changes, t, p99_max = [], t0, 0.0
    for _ in range(ticks):
        live = srv.streams
        for sid, (video, off, n) in specs.items():
            st = live.get(sid)
            if st is None:
                continue
            for j in range(n):
                idx = (off + st.frames_done + len(st.queue)) % len(video)
                srv.enqueue(sid, video[idx], t=t + j / n)
        t += srv.cfg.tick_s
        rep = srv.tick(t)
        changes.extend((rep.t,) + c for c in rep.cut_changes)
        if srv.last_link_report is not None:
            p99_max = max(p99_max, srv.last_link_report.p99_latency_s)
    return changes, t, p99_max


def _bitexact_row(ex, frames, cut, bits, label):
    """Serve one stream as one chunk; compare to the fused executor."""
    import jax.numpy as jnp

    from repro.camera.offload import ETH_25G_LINK
    from repro.camera.serve import ServeConfig, StreamingServer

    base = ex(jnp.asarray(frames))
    cfg = ServeConfig(chunk=len(frames), capacity=1, tick_s=1.0,
                      max_queue_s=1e9, link_window=4)
    srv = StreamingServer(ex, link=ETH_25G_LINK, config=cfg)
    dec = srv.register("s", fps=1.0, cut=cut, bits=bits)
    assert dec.admitted and dec.cut == cut, dec
    for i, f in enumerate(frames):
        srv.enqueue("s", f, t=i / len(frames))
    rep = srv.tick(t=1.0)
    (comp,) = rep.completions
    ok = True
    for k in ("motion", "n_windows", "n_auth", "scores", "window_id",
              "window_valid", "auth", "windows_dropped", "motion_dropped",
              "cascade_dropped"):
        if not np.array_equal(np.asarray(comp.result[k]),
                              np.asarray(getattr(base, k))):
            ok = False
    return ("serving", label, "1" if ok else "0",
            f"cut={cut or 'local'} bits={bits or 'raw'} "
            f"chunk={len(frames)} vs FaceAuthExecutor.__call__")


def rows(smoke: bool = False):
    from repro.camera.offload import BACKSCATTER
    from repro.camera.serve import FA_CUTS, ServeConfig, StreamingServer

    out = []
    ex, ctl, quiet, hot, calib = _setup(smoke)
    if smoke:
        n_a, n_b, ticks_a, ticks_b = 6, 3, 6, 6
        hot_fps = 2.0
        cfg = ServeConfig(chunk=4, capacity=4, slo_s=2.0, tick_s=1.0,
                          max_queue_s=8.0, resolve_every=4, link_window=2,
                          admit_util=0.9, stats_window=8)
    else:
        # resolve_every=32: a quiet stream's first re-solve lands after the
        # hot wave joins, so cut churn is congestion-driven rather than
        # zero-motion-window noise (a 4-chunk window with no motion makes
        # the motion cut look byte-free)
        n_a, n_b, ticks_a, ticks_b = 904, 120, 24, 24
        hot_fps = 2.0
        # slo_s covers the worst post-adaptation tick: up to three live
        # placement groups (local + vj + the nn retreat), each one
        # capacity-padded funnel dispatch plus the fleet-wide scorer
        cfg = ServeConfig(chunk=4, capacity=96, slo_s=2.5, tick_s=1.0,
                          max_queue_s=8.0, resolve_every=32, link_window=4,
                          admit_util=0.9, stats_window=8)

    # provision the shared uplink for the quiet fleet with ~55% headroom:
    # measured mean vj bytes set the scale, so the hot wave (whose real
    # traffic dwarfs its admission prior) is what pushes util past 1
    q_chunk_b = _mean_chunk_bytes(ex, quiet[:2], "vj", 8, cfg.chunk)
    n_local = sum(1 for k in range(n_a) if k % 32 == 31)
    fleet_bps = (n_a - n_local) * q_chunk_b / cfg.chunk
    link = BACKSCATTER.scaled(max(fleet_bps / 0.45, 1.0)
                              / BACKSCATTER.bytes_per_s)

    srv = StreamingServer(ex, link=link, controller=ctl, config=cfg)
    # vj is the fleet's unconstrained energy optimum (the controller picks
    # it for both traffic classes), nn the congestion fallback; compile
    # every rung x batch-shape bucket the sweep can reach before the
    # measured ticks (steady state offers ~fleet/chunk ready chunks per
    # tick to one rung, plus the hot wave)
    peak_ready = (n_a - n_local) // cfg.chunk + n_b + cfg.capacity
    srv.prewarm(([(None, None)] if n_local else [])
                + [(c, 8) for c in FA_CUTS], max_ready=peak_ready)

    # phase A: quiet fleet at the equilibrium cut (+ a few local feeds)
    specs = {}
    admitted = rejected = replaced = 0
    for k in range(n_a):
        sid = f"q{k}"
        cut = None if k % 32 == 31 else "vj"
        dec = srv.register(sid, fps=1.0, cut=cut, bits=8 if cut else None,
                           motion_frac=0.1)
        if not dec.admitted:
            rejected += 1
            continue
        admitted += 1
        replaced += dec.cut != cut
        vid = quiet[k % len(quiet)]
        # phase-shift each stream into its video so motion bursts (and
        # chunk readiness) do not synchronize across the fleet
        specs[sid] = (vid, (k * 7) % len(vid), 1)
        for j in range(k % cfg.chunk):
            srv.enqueue(sid, vid[(k * 7 + j) % len(vid)], t=0.0)
    srv.batch_lat_s.clear()
    changes_a, t, p99_link_a = _drive(srv, specs, ticks_a, t0=0.0)

    # phase B: hot wave — real traffic blows past the admission prior and
    # the windowed re-solves must retreat toward cheaper cuts
    for k in range(n_b):
        sid = f"h{k}"
        dec = srv.register(sid, fps=hot_fps, cut="vj", bits=8, t=t,
                           motion_frac=0.15)
        if not dec.admitted:
            rejected += 1
            continue
        admitted += 1
        replaced += dec.cut != "vj"
        vid = hot[k % len(hot)]
        specs[sid] = (vid, (k * 5) % len(vid), int(hot_fps))
        for j in range(k % cfg.chunk):
            srv.enqueue(sid, vid[(k * 5 + j) % len(vid)], t=t)
    changes_b, t, p99_link_b = _drive(srv, specs, ticks_b, t0=t)

    n_streams = len(srv.streams)
    p99_batch = srv.p99_batch_s()
    slo_ok = p99_batch <= cfg.slo_s
    resolves = srv.total_resolves()
    all_changes = changes_a + changes_b
    changed_streams = {c[1] for c in all_changes}
    requeues = sum(s.requeues for s in srv.streams.values())
    sim_fps = srv.frames_served() / max(t, 1e-9)

    out.append(("serving", "streams_sustained", n_streams,
                f"phaseA={n_a} phaseB={n_b} admitted={admitted} "
                f"rejected={rejected} re-placed={replaced}"))
    out.append(("serving", "p99_batch_s", f"{p99_batch:.4f}",
                f"SLO={cfg.slo_s}s capacity={cfg.capacity} "
                f"chunk={cfg.chunk}"))
    out.append(("serving", "slo_ok", "1" if slo_ok else "0",
                "measured p99 micro-batch dispatch latency under the SLO"))
    out.append(("serving", "throughput_fps", f"{sim_fps:.1f}",
                f"{srv.frames_served()} frames over {t:.0f}s simulated"))
    out.append(("serving", "resolves_fired", resolves,
                f"windowed CutController re-solves (cadence: every "
                f"{cfg.resolve_every} served frames)"))
    out.append(("serving", "cut_changes", len(all_changes),
                f"streams_changed={len(changed_streams)} "
                f"phaseA={len(changes_a)} phaseB={len(changes_b)}"))
    out.append(("serving", "link_p99_s",
                f"A={p99_link_a:.4f} B={p99_link_b:.4f}",
                f"max simulate_shared_link p99 per phase "
                f"({cfg.link_window}-tick windows, {link.name})"))
    out.append(("serving", "requeued_chunks", requeues,
                "capacity-overflow survivors re-queued (deterministic "
                "dropped_capacity_idx), never dropped"))

    out.append(_bitexact_row(ex, calib, None, None, "serve_bitexact_local"))
    out.append(_bitexact_row(ex, calib, "vj", None, "serve_bitexact_vj_raw"))

    assert resolves >= 1, "no windowed re-solve fired"
    assert slo_ok, f"p99 batch latency {p99_batch:.3f}s over {cfg.slo_s}s SLO"
    assert all(r[2] == "1" for r in out if r[1].startswith("serve_bitexact")), \
        "serving outputs diverged from the fused executor"
    if not smoke:
        assert n_streams >= 1000, f"only {n_streams} streams sustained"
        assert all_changes, "no stream's cut adapted across the sweep"
    return out
