"""VR depth hot path (paper §IV): seed per-pair jnp oracle vs the
rig-resident fused executor.

Timed configurations on a synthetic 8-pair rig at the working resolution
(a 1/8-linear-scale 4K tile per camera — the CPU-host stand-in for the
paper's per-pair FPGA slice; the oracle at full 4K is minutes per frame,
and the claim is the *ratio*):

  oracle — the seed ``bssa_depth_ref`` dataflow, kept verbatim: a Python
           loop over the rig's pairs, each materializing D+1 full-frame
           SAD maps (one integral image per disparity hypothesis)
           eagerly, then the scan-refine.  Timed warm (per-op caches
           populated) — the steady-state number, not first-call compile;
  fused  — ``VRRigExecutor``: the chunked cost-volume integral image +
           vectorized argmin, splat, ``refine_grid``, slice — one vmapped
           jit region per rig frame on a single device;
  rig    — the same executor pmapped one pair per device (the paper's
           8-parallel-FPGA rig shape), measured in a subprocess with 8
           host devices (same mechanism as tests/conftest.py).

Also times the batched panorama composition and reports fused-vs-oracle
per-block ms plus output parity (same argmin disparities up to
fp-borderline ties; refined depth within tolerance) — the acceptance
criteria of the fused rewrite.
"""

from __future__ import annotations

import functools
import json
import sys
import time

import numpy as np

from benchmarks.timing import run_json_child, timed as _timed

WORK_H, WORK_W = 270, 480          # 1/8-linear-scale 4K per camera
N_PAIRS = 8                        # the 16-camera rig
MAX_DISP = 32                      # VRWorkloadStats.disp_range
N_ITERS = 8
SIGMA = 16


def _rig(h=WORK_H, w=WORK_W, n_pairs=N_PAIRS):
    import jax.numpy as jnp

    from repro.camera.synthetic import stereo_pair

    pairs = [stereo_pair(h=h, w=w, seed=s) for s in range(n_pairs)]
    lefts = jnp.stack([jnp.asarray(p[0]) for p in pairs])
    rights = jnp.stack([jnp.asarray(p[1]) for p in pairs])
    return lefts, rights


def _rig_parallel_child():
    """Runs under XLA_FLAGS=--xla_force_host_platform_device_count=8:
    measures the pmapped executor and prints one JSON line."""
    import jax

    from repro.camera.bssa import GridSpec
    from repro.camera.pipelines import VRRigExecutor

    lefts, rights = _rig()
    ex = VRRigExecutor(GridSpec(sigma_spatial=SIGMA), max_disp=MAX_DISP,
                       n_iters=N_ITERS, rig_parallel=True)
    t_depth, depths = _timed(ex.depth_maps, lefts, rights)
    t_pano, _ = _timed(lambda: ex.panorama(lefts, rights, depths))
    print(json.dumps({"depth_ms": 1e3 * t_depth, "pano_ms": 1e3 * t_pano,
                      "n_devices": jax.local_device_count()}))


def _rig_parallel_ms():
    """Launch the pmap measurement in a subprocess with 8 CPU devices
    (the in-process backend is already initialized single-device)."""
    return run_json_child(["benchmarks.vr_depth_hotpath", "--rig-child"])


def rows(n_oracle_pairs: int = 2, smoke: bool = False):
    import jax
    import jax.numpy as jnp

    from repro.camera.bssa import (
        GridSpec, bssa_depth_ref, refine, rough_disparity,
        rough_disparity_ref, slice_grid, splat)
    from repro.camera.pipelines import VR_FPS_TARGET, VRRigExecutor
    from repro.kernels.bilateral_blur.ops import refine_grid

    out = []
    spec = GridSpec(sigma_spatial=SIGMA)
    if smoke:                      # toy tile, no subprocess: CI liveness only
        n_oracle_pairs = 1
        lefts, rights = _rig(h=64, w=96, n_pairs=2)
    else:
        lefts, rights = _rig()
    n_pairs = int(lefts.shape[0])

    # ---- fused: whole rig frame through the executor (single device) --------
    ex = VRRigExecutor(spec, max_disp=MAX_DISP, n_iters=N_ITERS)
    t_depth, depths = _timed(ex.depth_maps, lefts, rights)
    t_pano, _ = _timed(lambda: ex.panorama(lefts, rights, depths))

    # ---- rig-parallel: one pair per device (subprocess, 8 CPU devices) ------
    rig = None if smoke else _rig_parallel_ms()

    # ---- oracle: the seed per-pair Python loop, eager, warm -----------------
    bssa_depth_ref(lefts[0], rights[0], spec, MAX_DISP,
                   N_ITERS).block_until_ready()        # warm per-op caches
    t0 = time.time()
    oracle = [bssa_depth_ref(lefts[i], rights[i], spec, MAX_DISP, N_ITERS)
              for i in range(n_oracle_pairs)]
    oracle[-1].block_until_ready()
    t_oracle_pair = (time.time() - t0) / n_oracle_pairs

    # ---- parity -------------------------------------------------------------
    rough_f = np.asarray(jax.vmap(
        functools.partial(rough_disparity, max_disp=MAX_DISP))(
            lefts[:n_oracle_pairs], rights[:n_oracle_pairs]))
    rough_o = np.stack([np.asarray(rough_disparity_ref(
        lefts[i], rights[i], MAX_DISP)) for i in range(n_oracle_pairs)])
    argmin_match = float((rough_f == rough_o).mean())
    depth_err = max(float(jnp.abs(depths[i] - oracle[i]).max())
                    for i in range(n_oracle_pairs))

    # ---- per-block ms: fused (jitted) vs oracle (eager, warm), one pair -----
    l0, r0 = lefts[0], rights[0]
    blocks = []
    t, rough0 = _timed(jax.jit(functools.partial(
        rough_disparity, max_disp=MAX_DISP)), l0, r0)
    blocks.append(("rough", t))
    t, (gv, gw) = _timed(jax.jit(functools.partial(splat, spec=spec)),
                         l0, rough0)
    blocks.append(("splat", t))
    t, (gv, gw) = _timed(functools.partial(refine_grid, n_iters=N_ITERS),
                         gv, gw)
    blocks.append(("refine", t))
    t, _ = _timed(jax.jit(functools.partial(slice_grid, spec=spec)),
                  gv, gw, l0)
    blocks.append(("slice", t))

    t_or, o_rough = _timed(functools.partial(rough_disparity_ref,
                                             max_disp=MAX_DISP), l0, r0)
    t_os, (ogv, ogw) = _timed(splat, l0, o_rough, spec)
    t_orf, (ogv, ogw) = _timed(functools.partial(refine, n_iters=N_ITERS),
                               ogv, ogw)
    t_osl, _ = _timed(slice_grid, ogv, ogw, l0, spec)
    oracle_blocks = dict(rough=t_or, splat=t_os, refine=t_orf, slice=t_osl)

    # ---- rows ---------------------------------------------------------------
    fused_pair_ms = 1e3 * t_depth / n_pairs
    speedup_1dev = t_oracle_pair * 1e3 / fused_pair_ms
    out.append(("vr_depth", "working_resolution",
                f"{lefts.shape[2]}x{lefts.shape[1]}x{n_pairs}pairs",
                f"{'SMOKE tile' if smoke else '1/8-linear 4K per camera'}, "
                f"D={MAX_DISP}, {N_ITERS} iters, sigma={SIGMA}"))
    out.append(("vr_depth", "oracle_ms_per_pair", f"{1e3*t_oracle_pair:.1f}",
                f"seed eager loop, warm, {n_oracle_pairs} pairs timed"))
    out.append(("vr_depth", "fused_ms_per_pair_1dev", f"{fused_pair_ms:.1f}",
                "vmapped executor, single device, rig batch amortized"))
    out.append(("vr_depth", "speedup_fused_1dev", f"{speedup_1dev:.1f}x",
                "fusion alone, same device count as the oracle"))
    if rig:
        rig_pair_ms = rig["depth_ms"] / N_PAIRS
        t_rig_frame = (rig["depth_ms"] + rig["pano_ms"]) / 1e3
        out.append(("vr_depth", "rig_ms_per_pair", f"{rig_pair_ms:.1f}",
                    "pmapped executor, one pair per device x8 (the paper's "
                    "8-parallel-FPGA rig shape)"))
        out.append(("vr_depth", "speedup_vs_seed",
                    f"{1e3*t_oracle_pair/rig_pair_ms:.1f}x",
                    "acceptance: >= 10x (paper: up to 10x FPGA vs CPU/GPU "
                    "on the depth block)"))
        out.append(("vr_depth", "rig_depth_ms_per_frame",
                    f"{rig['depth_ms']:.0f}", "8 pairs, pmapped"))
        out.append(("vr_depth", "fused_rig_fps", f"{1/t_rig_frame:.1f}",
                    f"depth+panorama; target {VR_FPS_TARGET:.0f} (paper: "
                    "only accelerated BSSA clears it)"))
    else:
        out.append(("vr_depth", "speedup_vs_seed", f"{speedup_1dev:.1f}x",
                    "rig-parallel subprocess unavailable; single-device "
                    "fusion number"))
    out.append(("vr_depth", "pano_ms_per_rig_frame", f"{1e3*t_pano:.1f}",
                "batched warp + scatter blend, both eyes"))
    for name, t in blocks:
        out.append(("vr_depth", f"block_{name}_ms",
                    f"oracle={1e3*oracle_blocks[name]:.1f} fused={1e3*t:.2f}",
                    "per pair, single device"))
    out.append(("vr_depth", "argmin_parity", f"{argmin_match:.4f}",
                "fraction of pixels with identical disparity hypothesis"))
    out.append(("vr_depth", "depth_max_abs_diff", f"{depth_err:.2e}",
                "fused vs oracle refined depth"))
    return out


def main():
    if "--rig-child" in sys.argv:
        _rig_parallel_child()
        return
    for row in rows():
        print(",".join(str(c) for c in row))


if __name__ == "__main__":
    main()
