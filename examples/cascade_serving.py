"""Cascade serving: a cheap scorer filters requests before the big LM —
the paper's motion->VJ->NN insight applied to an inference cluster
(DESIGN.md §2).

A tiny 2-layer scorer estimates whether a prompt needs the big model
(here: a proxy task — high next-token entropy under the small model);
survivors are compacted to a static capacity batch (core/cascade.py) and
decoded by the large model.  Prints the measured FLOP reduction against
serving everything with the big model.

    PYTHONPATH=src python examples/cascade_serving.py
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core.cascade import cascade_flops
from repro.models.transformer import Model
from repro.serve.engine import cascade_serve, generate, SamplerConfig


def main():
    big_cfg = get_config("yi-9b", smoke=True)
    small_cfg = dataclasses.replace(big_cfg, n_layers=1, d_model=32,
                                    n_heads=2, n_kv=1, d_head=16, d_ff=64,
                                    name="yi-scorer")
    big = Model(big_cfg)
    small = Model(small_cfg)
    kb, ks = jax.random.split(jax.random.PRNGKey(0))
    big_params = big.init(kb)
    small_params = small.init(ks)

    B, S = 32, 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, big_cfg.vocab)

    def scorer(batch):
        logits, _ = small.logits(small_params, batch)
        lg = logits[:, -1].astype(jnp.float32)
        p = jax.nn.softmax(lg, axis=-1)
        return -jnp.sum(p * jnp.log(p + 1e-9), axis=-1)   # entropy

    def big_serve(batch):
        return generate(big, big_params, batch, 8,
                        sampler=SamplerConfig(temperature=0.0))

    # threshold: median scorer entropy (half the fleet load filtered)
    thr = float(jnp.median(scorer(prompts)))
    out, served, stats = cascade_serve(scorer, big_serve, prompts,
                                       threshold=thr, capacity_fraction=0.5)
    print(f"[cascade] {B} requests -> {int(stats['n_candidates'])} pass scorer "
          f"-> {int(stats['n_served'])} served by the big model "
          f"({int(stats['n_dropped_capacity'])} capacity-dropped)")

    flops_small = 2 * small.n_active_params()
    flops_big = 2 * big.n_active_params() * 9  # prefill+8 decode steps amortized
    naive = cascade_flops([flops_big], [1.0])
    casc = cascade_flops([flops_small, flops_big],
                         [float(stats["n_served"]) / B, 1.0])
    print(f"[cascade] per-request FLOPs: naive {naive:.3e} vs cascade {casc:.3e}"
          f" -> {100 * (1 - casc / naive):.0f}% cheaper (scorer overhead "
          f"{100 * flops_small / naive:.2f}%)")
    print(f"[cascade] outputs shape {out.shape}, served mask sum "
          f"{int(served.sum())}")


if __name__ == "__main__":
    main()
