"""End-to-end §IV pipeline on synthetic stereo: the rig-resident fused
executor (batched BSSA depth + stereo panorama), then the Fig. 14
throughput ladder for CPU/GPU/FPGA placements.

    PYTHONPATH=src python examples/camera_vr_video.py
"""

import time

import numpy as np
import jax.numpy as jnp

from repro.camera.bssa import GridSpec, ms_ssim
from repro.camera.pipelines import (
    VR_FPS_TARGET, VRRigExecutor, VRWorkloadStats, vr_pipeline, vr_profiles)
from repro.camera.synthetic import stereo_pair
from repro.core.costmodel import (
    ARM_A9, ETH_25G, ETH_400G, QUADRO_GPU, VIRTEX_FPGA, ZYNQ_FPGA,
    throughput_cost)


def main():
    # 1. an 8-pair rig through the fused executor (reduced resolution for CPU)
    pairs = [stereo_pair(h=128, w=160, seed=s) for s in range(8)]
    lefts = jnp.stack([jnp.asarray(p[0]) for p in pairs])
    rights = jnp.stack([jnp.asarray(p[1]) for p in pairs])
    ex = VRRigExecutor(GridSpec(sigma_spatial=8), max_disp=12, n_iters=8)
    lp, rp, depths = ex(lefts, rights)                 # compile + warm
    t0 = time.time()
    lp, rp, depths = ex(lefts, rights)
    lp.block_until_ready()
    wall = time.time() - t0
    print(f"[rig] 8-pair frame: {1e3*wall:.1f} ms ({1/wall:.1f} FPS), "
          f"panorama {lp.shape} x2, "
          f"finite={bool(jnp.all(jnp.isfinite(lp)) & jnp.all(jnp.isfinite(rp)))}")

    d, g = np.asarray(depths[2]), pairs[2][2]
    q = ms_ssim(jnp.asarray((d - d.min()) / (np.ptp(d) + 1e-9)),
                jnp.asarray((g - g.min()) / (np.ptp(g) + 1e-9)))
    print(f"[bssa] fused depth MS-SSIM vs ground truth (pair 2): {q:.3f}")

    # 2. Fig. 14 ladder at full 16-camera scale (cost model)
    pipe = vr_pipeline(VRWorkloadStats())
    print(f"\n[fig14] per-pair pipeline, 25 GbE uplink, target {VR_FPS_TARGET} FPS:")
    for name, dev, cut in [
        ("offload raw", ARM_A9, "capture"),
        ("offload after grid", ARM_A9, "grid"),
        ("CPU depth, full pipeline", ARM_A9, "stitch"),
        ("GPU depth, full pipeline", QUADRO_GPU, "stitch"),
        ("FPGA (eval Zynq) full", ZYNQ_FPGA, "stitch"),
        ("FPGA (target Virtex) full", VIRTEX_FPGA, "stitch"),
    ]:
        rep = throughput_cost(pipe, vr_profiles(dev), ETH_25G, cut)
        comm_fps = ETH_25G.link_bw / (8 * pipe.cut_payload_bytes(pipe.index(cut)))
        fps = min(rep.compute_fps, comm_fps)
        print(f"  {name:28s} {fps:8.1f} fps "
              f"({'REAL-TIME' if fps >= VR_FPS_TARGET else 'too slow'})")

    raw = 16 * pipe.cut_payload_bytes(0) / 2
    print(f"\n[net] raw 16-cam feed: {ETH_25G.link_bw/raw:.1f} fps on 25 GbE, "
          f"{ETH_400G.link_bw/raw:.0f} fps on 400 GbE (paper: 395) — fat links "
          f"flip the decision back to offload")


if __name__ == "__main__":
    main()
