"""End-to-end §IV pipeline on synthetic stereo: BSSA depth + stitching,
then the Fig. 14 throughput ladder for CPU/GPU/FPGA placements.

    PYTHONPATH=src python examples/camera_vr_video.py
"""

import numpy as np
import jax.numpy as jnp

from repro.camera.bssa import GridSpec, bssa_depth, ms_ssim
from repro.camera.pipelines import (
    VR_FPS_TARGET, VRWorkloadStats, vr_pipeline, vr_profiles)
from repro.camera.stitch import stereo_panorama, stitch_ring
from repro.camera.synthetic import stereo_pair
from repro.core.costmodel import (
    ARM_A9, ETH_25G, ETH_400G, QUADRO_GPU, VIRTEX_FPGA, ZYNQ_FPGA,
    throughput_cost)


def main():
    # 1. depth from a synthetic stereo pair (reduced resolution for CPU)
    left, right, gt = stereo_pair(h=128, w=160, seed=2)
    depth = bssa_depth(jnp.asarray(left), jnp.asarray(right),
                       GridSpec(sigma_spatial=8), max_disp=12, n_iters=8)
    d, g = np.asarray(depth), gt
    q = ms_ssim(jnp.asarray((d - d.min()) / (np.ptp(d) + 1e-9)),
                jnp.asarray((g - g.min()) / (np.ptp(g) + 1e-9)))
    print(f"[bssa] depth MS-SSIM vs ground truth: {q:.3f}")

    # 2. stitch a 4-camera ring + stereo pair synthesis
    views = [stereo_pair(h=96, w=128, seed=s)[0] for s in range(4)]
    depths = [jnp.asarray(stereo_pair(h=96, w=128, seed=s)[2]) for s in range(4)]
    lp, rp = stereo_panorama(views, views, depths)
    print(f"[stitch] stereo panorama: {lp.shape} x2, "
          f"finite={bool(jnp.all(jnp.isfinite(lp)))}")

    # 3. Fig. 14 ladder at full 16-camera scale (cost model)
    pipe = vr_pipeline(VRWorkloadStats())
    print(f"\n[fig14] per-pair pipeline, 25 GbE uplink, target {VR_FPS_TARGET} FPS:")
    for name, dev, cut in [
        ("offload raw", ARM_A9, "capture"),
        ("offload after grid", ARM_A9, "grid"),
        ("CPU depth, full pipeline", ARM_A9, "stitch"),
        ("GPU depth, full pipeline", QUADRO_GPU, "stitch"),
        ("FPGA (eval Zynq) full", ZYNQ_FPGA, "stitch"),
        ("FPGA (target Virtex) full", VIRTEX_FPGA, "stitch"),
    ]:
        rep = throughput_cost(pipe, vr_profiles(dev), ETH_25G, cut)
        comm_fps = ETH_25G.link_bw / (8 * pipe.cut_payload_bytes(pipe.index(cut)))
        fps = min(rep.compute_fps, comm_fps)
        print(f"  {name:28s} {fps:8.1f} fps "
              f"({'REAL-TIME' if fps >= VR_FPS_TARGET else 'too slow'})")

    raw = 16 * pipe.cut_payload_bytes(0) / 2
    print(f"\n[net] raw 16-cam feed: {ETH_25G.link_bw/raw:.1f} fps on 25 GbE, "
          f"{ETH_400G.link_bw/raw:.0f} fps on 400 GbE (paper: 395) — fat links "
          f"flip the decision back to offload")


if __name__ == "__main__":
    main()
