"""Quickstart: train a reduced-config LM end-to-end on CPU, with
checkpointing and restart — the minimal tour of the public API.

    PYTHONPATH=src python examples/quickstart.py [--arch yi-9b] [--steps 120]
"""

import argparse
import shutil
import tempfile

import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models.transformer import Model
from repro.train.loop import LoopConfig, train
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)     # reduced config: CPU-sized
    model = Model(cfg)
    print(f"arch={cfg.name} (reduced): {model.n_params():,} params, "
          f"{model.n_periods}x{model.period} scanned layers")

    data = DataConfig(vocab=cfg.vocab, seq=64, global_batch=16, seed=0)
    make_batch = lambda s: {"tokens": jnp.asarray(batch_for_step(data, s)["tokens"])}

    ckpt_dir = tempfile.mkdtemp(prefix="repro_quickstart_")
    try:
        params, _, out = train(
            model, make_batch,
            LoopConfig(total_steps=args.steps, ckpt_every=40, ckpt_dir=ckpt_dir),
            AdamWConfig(lr_peak=3e-3, warmup_steps=20, decay_steps=args.steps),
        )
        hist = out["history"]
        print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"over {len(hist)} steps")
        assert hist[-1]["loss"] < hist[0]["loss"], "training must make progress"
        print("quickstart OK")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
