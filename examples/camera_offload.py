"""One face-auth camera stream over the RFID-backscatter link, end to end.

The §III system as the paper deployed it: an energy-harvesting WISPCam
node, an offload decision, and a reader uplink.  This example drives the
full loop on live executors (DESIGN.md §10):

  1. train the detector cascade + NN, calibrate the fused executor;
  2. calibrate the cut controller: run every legal cut's split executor
     (node jit | wire payload | cloud jit), measuring wall clock and the
     bytes each cut actually puts on the air (8-bit wire codec);
  3. feed the measured Block descriptors to ``solve_cut`` and execute the
     chosen cut — node half produces the payload, cloud half finishes the
     funnel; verify the offloaded result matches the on-node executor;
  4. replay the measured per-frame byte trace through the backscatter
     link simulator, alone and contending with a 8-camera fleet.

    PYTHONPATH=src python examples/camera_offload.py
"""

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.camera.face_nn import train_face_nn
from repro.camera.offload import (
    BACKSCATTER,
    CutController,
    FaceAuthOffloadExecutor,
    simulate_shared_link,
)
from repro.camera.pipelines import (
    FAWorkloadStats,
    FaceAuthExecutor,
    calibrate_fa,
    fa_pipeline,
    fa_profiles,
)
from repro.camera.synthetic import face_dataset, security_video
from repro.camera.viola_jones import make_feature_pool, train_cascade

CUTS = ("sensor", "motion", "vj", "nn")


def main():
    # 1. workload + fused on-node executor (the baseline placement)
    X, y, _meta = face_dataset(n_per_class=400, seed=0)
    nn = train_face_nn(X, y, steps=1500)
    casc = train_cascade(X, y, make_feature_pool(n=250), n_stages=10,
                         per_stage=33)
    frames, _truth = security_video()
    fj = jnp.asarray(frames)
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2])
    ex.calibrate(frames)
    base = ex(fj)
    n_motion = int(np.asarray(base.motion).sum())
    n_windows = int(np.asarray(base.n_windows).sum())
    print(f"[funnel] {len(frames)} frames -> {n_motion} motion -> "
          f"{n_windows} windows -> {int(np.asarray(base.n_auth).sum())} auth")

    # 2. measured calibration of every cut (8-bit wire codec)
    stats = FAWorkloadStats(n_frames=len(frames),
                            motion_frames=max(n_motion, 1),
                            windows_to_nn=max(n_windows, 1))
    cal = calibrate_fa(stats)
    profiles = fa_profiles()
    profiles["nn"] = cal.nn_profile()
    link = dataclasses.replace(BACKSCATTER,
                               joules_per_byte=cal.rf_joules_per_byte)
    ctl = CutController(
        lambda cut: FaceAuthOffloadExecutor(ex, cut, bits=8),
        cuts=CUTS, template=fa_pipeline(stats), profiles=profiles,
        link=link, regime="energy", unit_rate_hz=1.0,
        duties={"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0})
    print("\n[calibrate] split executors, measured per source frame:")
    for m in ctl.calibrate(fj):
        print(f"  cut={m.cut:7s} node={1e3*m.node_s:6.1f} ms "
              f"cloud={1e3*m.cloud_s:6.1f} ms "
              f"wire={m.bytes_per_unit:8.1f} B (padded "
              f"{m.capacity_bytes/len(frames):8.0f} B)")

    # 3. solve on the measured descriptors + execute the chosen cut
    rep = ctl.report()
    print("\n[solver] regime objective per cut (uW, measured bytes):")
    for cut in CUTS:
        mark = " <== chosen" if cut == rep.chosen_cut else ""
        print(f"  {cut:7s} {1e6*rep.measured_objectives[cut]:8.1f}"
              f" (predicted {1e6*rep.predicted_objectives[cut]:8.1f}){mark}")
    print(f"[solver] chosen={rep.chosen_cut} measured_best="
          f"{rep.measured_best_cut} agrees={rep.agrees} "
          f"predicted-vs-measured rank agreement={rep.rank_agreement:.2f}")
    result, payload, _sol = ctl.execute(fj)
    d_win = int(np.abs(np.asarray(base.n_windows)
                       - np.asarray(result.n_windows)).sum())
    d_auth = int(np.abs(np.asarray(base.n_auth)
                        - np.asarray(result.n_auth)).sum())
    # the raw split (bits=None) is pinned bit-exact in tests; the 8-bit
    # codec's funnel deltas below are the §III-A accuracy cost of the cut
    exact, _ = FaceAuthOffloadExecutor(ex, rep.chosen_cut, bits=None)(fj)
    raw_ok = np.array_equal(np.asarray(base.n_auth),
                            np.asarray(exact.n_auth))
    print(f"[execute] offloaded @8-bit: {payload.nbytes()/len(frames):.1f} "
          f"B/frame on the air; window/auth deltas vs on-node = "
          f"{d_win}/{d_auth} of {n_windows}/"
          f"{int(np.asarray(base.n_auth).sum())} (codec distortion); "
          f"raw split bit-exact: {raw_ok}")

    # 4. the chosen cut's trace over the backscatter reader
    m = {mm.cut: mm for mm in ctl.measurements}[rep.chosen_cut]
    if rep.chosen_cut in ("vj", "nn"):
        per_frame = np.asarray(base.n_windows, np.float64) * 400.0 + 16.0
    elif rep.chosen_cut == "motion":
        per_frame = np.asarray(base.motion, np.float64) * frames[0].size
    else:
        per_frame = np.full(len(frames), float(frames[0].size))
    per_frame *= m.bytes_per_unit * len(frames) / max(per_frame.sum(), 1.0)
    one = simulate_shared_link(per_frame, link, frame_period_s=1.0)
    fleet = simulate_shared_link(
        np.stack([np.roll(per_frame, 7 * s) for s in range(8)]),
        link, frame_period_s=1.0)
    print(f"\n[link] cut={rep.chosen_cut} on {link.name} "
          f"({link.bytes_per_s/1e3:.0f} kB/s, "
          f"{1e9*link.joules_per_byte:.1f} nJ/B)")
    print(f"  1 camera : mean latency {one.mean_latency_s:6.3f} s, "
          f"util {100*one.utilization:4.1f}%, "
          f"{1e6*one.joules/len(frames):.2f} uJ/frame")
    print(f"  8 cameras: mean latency {fleet.mean_latency_s:6.3f} s, "
          f"p99 {fleet.p99_latency_s:.3f} s, util "
          f"{100*fleet.utilization:4.1f}% — one reader carries the fleet "
          f"only because the funnel already shrank the payload")


if __name__ == "__main__":
    main()
