"""Multi-pod training driver (the production entry point, exercised at CPU
scale): builds the (pod, data, model) mesh from fake devices, shards a
reduced model with the plan the placement solver recommends, runs real
steps with int8-compressed pod-axis gradient exchange, and round-trips an
elastic checkpoint.

    PYTHONPATH=src python examples/multipod_train.py          # 8 fake devices
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models.layers import param_shardings
from repro.models.transformer import Model
from repro.parallel.axes import use_sharding
from repro.parallel.plans import plan_rules
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import (
    init_ef_states, make_train_step, make_train_step_compressed)


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({len(jax.devices())} devices)")

    cfg = dataclasses.replace(get_config("yi-9b", smoke=True),
                              param_dtype=jnp.float32)
    model = Model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq=32, global_batch=8, seed=0)
    opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=5, decay_steps=50)

    # the fsdp plan is the solver's recommendation for this arch/shape and
    # the configuration validated by the 512-device dry-run
    with use_sharding(mesh, plan_rules("fsdp")) as ctx:
        shardings = param_shardings(model.specs(), ctx)
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), shardings)
        opt = init_opt_state(params)
        ef = init_ef_states(params)

        plain = jax.jit(make_train_step(model, opt_cfg))
        compressed = jax.jit(make_train_step_compressed(model, opt_cfg))

        # A/B the pod-axis gradient exchange (paper's early data reduction)
        losses_p, losses_c = [], []
        params_c, opt_c = params, opt
        for step in range(20):
            batch = {"tokens": jnp.asarray(batch_for_step(data, step)["tokens"])}
            params, opt, m1 = plain(params, opt, batch)
            params_c, opt_c, ef, m2 = compressed(params_c, opt_c, ef, batch)
            losses_p.append(float(m1["loss"]))
            losses_c.append(float(m2["loss"]))
        print(f"plain      loss: {losses_p[0]:.4f} -> {losses_p[-1]:.4f}")
        print(f"compressed loss: {losses_c[0]:.4f} -> {losses_c[-1]:.4f} "
              f"(int8+EF pod all-reduce; final gap "
              f"{abs(losses_p[-1]-losses_c[-1]):.4f})")
        assert losses_c[-1] < losses_c[0], "compressed training must converge"

    print("multipod driver OK")


if __name__ == "__main__":
    main()
