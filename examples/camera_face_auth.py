"""End-to-end reproduction of the paper's §III pipeline on synthetic video:

  capture -> motion detection -> Viola-Jones -> 400-8-1 NN (int8 + LUT)

Trains the face NN, fits the VJ cascade, runs the full filter chain over a
security-style video, and evaluates every offload configuration with the
calibrated cost model — printing the Fig. 8 ladder and the Fig. 9 +28%
result as measured on THIS run's funnel.

    PYTHONPATH=src python examples/camera_face_auth.py
"""

import numpy as np
import jax.numpy as jnp

from repro.camera.face_nn import (
    classification_error, forward_quantized, make_sigmoid_lut, train_face_nn)
from repro.camera.motion import motion_mask
from repro.camera.pipelines import (
    FAWorkloadStats, calibrate_fa, fa_pipeline, fa_profiles)
from repro.camera.synthetic import face_dataset, security_video
from repro.camera.viola_jones import (
    FusedDetector, extract_windows, make_feature_pool, train_cascade)
from repro.core.costmodel import energy_cost, IMAGE_SENSOR, MOTION_ASIC, VJ_ASIC
from repro.core.placement import solve_cut


def main():
    # 1. train the authenticator (f32) and fit the detector cascade
    X, y, meta = face_dataset(n_per_class=400, seed=0)
    ntr = int(0.9 * len(X))
    nn = train_face_nn(X[:ntr], y[:ntr], steps=2500)
    lut, lmeta = make_sigmoid_lut()
    err = classification_error(
        forward_quantized(nn, jnp.asarray(X[ntr:]), 8, lut, lmeta), y[ntr:])
    print(f"[nn] int8+LUT test error: {err*100:.1f}%")

    pool = make_feature_pool(n=250)
    casc = train_cascade(X[:ntr], y[:ntr], pool, n_stages=10, per_stage=33)
    print(f"[vj] cascade: {casc.n_stages} stages x {casc.stage_sizes[0]} features")

    # 2. run the funnel over the synthetic security video — VJ through the
    # frame-resident fused front-end (one integral image per frame, gathered
    # Haar features, compacting cascade with capacities calibrated on the
    # first motion frames)
    frames, truth = security_video()
    mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
    mask = np.asarray(mask)
    midx = np.where(mask)[0]
    windows_fired = 0
    auth_hits = 0
    if len(midx):
        det = FusedDetector(casc, frames.shape[1], frames.shape[2])
        caps = det.calibrate(frames[midx[:4]])
        print(f"[vj] compacting capacities (calibrated): {caps}")
        all_dets, dstats = det.detect(frames[midx])
        if dstats["dropped"]:
            print(f"[vj] WARNING: {dstats['dropped']} windows dropped at "
                  "capacity — funnel counts are a lower bound")
        for i, dets in zip(midx, all_dets):
            if not dets:
                continue
            wins = extract_windows(frames[i], dets)
            scores = forward_quantized(
                nn, jnp.asarray(wins.reshape(len(wins), -1)), 8, lut, lmeta)
            windows_fired += len(dets)
            auth_hits += int((np.asarray(scores) > 0.5).sum())
    print(f"[funnel] {len(frames)} frames -> {int(mask.sum())} motion "
          f"-> {windows_fired} windows -> {auth_hits} authentications")

    # 3. cost every configuration with the calibrated model
    stats = FAWorkloadStats(
        n_frames=len(frames), motion_frames=int(mask.sum()),
        windows_to_nn=max(windows_fired, 1))
    cal = calibrate_fa(stats)
    pipe = fa_pipeline(stats)
    profiles = fa_profiles()
    profiles["nn"] = cal.nn_profile()
    duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}

    print("\n[fig8] configuration ladder (measured funnel):")
    for name, opts, cut in [
        ("raw offload", (), "sensor"),
        ("motion only", ("motion",), "motion"),
        ("motion+VJ, offload NN", ("motion", "vj"), "vj"),
        ("full pipeline (NN in-camera)", ("motion", "vj"), "nn"),
    ]:
        rep = energy_cost(pipe.configure(opts), profiles, cal.rf_link(), cut,
                          duties=duties)
        print(f"  {name:32s} {rep.total_w*1e6:9.1f} uW "
              f"(compute {rep.compute_w*1e6:7.1f} / comm {rep.comm_w*1e6:7.1f})")

    a = energy_cost(pipe.configure(("motion", "vj")), profiles, cal.rf_link(),
                    "vj", duties=duties).total_w
    b = energy_cost(pipe.configure(("motion", "vj")), profiles, cal.rf_link(),
                    "nn", duties=duties).total_w
    print(f"\n[fig9] NN in-camera costs {100*(b/a-1):+.1f}% (paper: +28%) -> "
          f"offload the NN, keep the filters")

    sol = solve_cut(pipe, profiles, cal.rf_link(), regime="energy", duties=duties)
    print(f"[solver] optimal configuration: {sol.report.config_name} "
          f"at {sol.report.total_w*1e6:.1f} uW")


if __name__ == "__main__":
    main()
