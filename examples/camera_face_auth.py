"""End-to-end reproduction of the paper's §III pipeline on synthetic video:

  capture -> motion detection -> Viola-Jones -> 400-8-1 NN (int8 + LUT)

Trains the face NN, fits the VJ cascade, then runs the funnel through the
SHIPPED hot path — ``FaceAuthExecutor``, the single-dispatch streaming
executor (motion gate, fused detection, capacity-padded window gathers,
int8-kernel NN tail) — and cross-checks its funnel counts against the
per-motion-frame host loop (the golden oracle: ``FusedDetector.detect``
+ numpy ``extract_windows`` + ``forward_quantized``).  Finally evaluates
every offload configuration with the calibrated cost model — printing the
Fig. 8 ladder and the Fig. 9 +28% result as measured on THIS run's funnel.

    PYTHONPATH=src python examples/camera_face_auth.py
"""

import numpy as np
import jax.numpy as jnp

from repro.camera.face_nn import (
    classification_error, forward_quantized, make_sigmoid_lut, train_face_nn)
from repro.camera.motion import motion_mask
from repro.camera.pipelines import (
    FAWorkloadStats, FaceAuthExecutor, calibrate_fa, fa_pipeline, fa_profiles)
from repro.camera.synthetic import face_dataset, security_video
from repro.camera.viola_jones import (
    extract_windows, make_feature_pool, train_cascade)
from repro.core.costmodel import energy_cost
from repro.core.placement import solve_cut


def main():
    # 1. train the authenticator (f32) and fit the detector cascade
    X, y, meta = face_dataset(n_per_class=400, seed=0)
    ntr = int(0.9 * len(X))
    nn = train_face_nn(X[:ntr], y[:ntr], steps=2500)
    lut, lmeta = make_sigmoid_lut()
    err = classification_error(
        forward_quantized(nn, jnp.asarray(X[ntr:]), 8, lut, lmeta), y[ntr:])
    print(f"[nn] int8+LUT test error: {err*100:.1f}%")

    pool = make_feature_pool(n=250)
    casc = train_cascade(X[:ntr], y[:ntr], pool, n_stages=10, per_stage=33)
    print(f"[vj] cascade: {casc.n_stages} stages x {casc.stage_sizes[0]} features")

    # 2. the shipped hot path: the whole funnel in ONE device dispatch per
    # batch (motion gate -> frame compaction -> fused VJ -> capacity-padded
    # window gathers -> int8-kernel NN), capacities calibrated from the
    # workload itself
    frames, truth = security_video()
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                          lut=lut, lut_meta=lmeta)
    fcap, wcap, vj_caps = ex.calibrate(frames)
    print(f"[exec] calibrated capacities: frames={fcap} windows={wcap} "
          f"vj={vj_caps}")
    res = ex(frames)
    ex_motion = int(np.asarray(res.motion).sum())
    ex_windows = int(np.asarray(res.n_windows).sum())
    ex_auth = int(np.asarray(res.n_auth).sum())
    if res.total_dropped():
        print(f"[exec] WARNING: {res.total_dropped()} frames/windows "
              "dropped at capacity — funnel counts are a lower bound")
    print(f"[funnel] {len(frames)} frames -> {ex_motion} motion "
          f"-> {ex_windows} windows -> {ex_auth} authentications "
          "(streaming executor)")

    # 3. cross-check: the per-motion-frame host loop (golden oracle) must
    # reproduce the executor's funnel exactly (the NN scores differ only by
    # quantization scheme: static int8 scales vs per-tensor fake-quant)
    mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
    mask = np.asarray(mask)
    midx = np.where(mask)[0]
    windows_fired = 0
    auth_hits = 0
    if len(midx):
        all_dets, dstats = ex.det.detect(frames[midx])
        if dstats["dropped"]:
            print(f"[vj] WARNING: {dstats['dropped']} windows dropped at "
                  "capacity — funnel counts are a lower bound")
        for i, dets in zip(midx, all_dets):
            if not dets:
                continue
            wins = extract_windows(frames[i], dets)
            scores = forward_quantized(
                nn, jnp.asarray(wins.reshape(len(wins), -1)), 8, lut, lmeta)
            windows_fired += len(dets)
            auth_hits += int((np.asarray(scores) > 0.5).sum())
    agree = (int(mask.sum()) == ex_motion) and (windows_fired == ex_windows)
    print(f"[check] host loop: {int(mask.sum())} motion -> {windows_fired} "
          f"windows -> {auth_hits} auth (fake-quant NN) | counts "
          f"{'MATCH' if agree else 'MISMATCH'} vs executor")

    # 4. cost every configuration with the calibrated model
    stats = FAWorkloadStats(
        n_frames=len(frames), motion_frames=ex_motion,
        windows_to_nn=max(ex_windows, 1))
    cal = calibrate_fa(stats)
    pipe = fa_pipeline(stats)
    profiles = fa_profiles()
    profiles["nn"] = cal.nn_profile()
    duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}

    print("\n[fig8] configuration ladder (measured funnel):")
    for name, opts, cut in [
        ("raw offload", (), "sensor"),
        ("motion only", ("motion",), "motion"),
        ("motion+VJ, offload NN", ("motion", "vj"), "vj"),
        ("full pipeline (NN in-camera)", ("motion", "vj"), "nn"),
    ]:
        rep = energy_cost(pipe.configure(opts), profiles, cal.rf_link(), cut,
                          duties=duties)
        print(f"  {name:32s} {rep.total_w*1e6:9.1f} uW "
              f"(compute {rep.compute_w*1e6:7.1f} / comm {rep.comm_w*1e6:7.1f})")

    a = energy_cost(pipe.configure(("motion", "vj")), profiles, cal.rf_link(),
                    "vj", duties=duties).total_w
    b = energy_cost(pipe.configure(("motion", "vj")), profiles, cal.rf_link(),
                    "nn", duties=duties).total_w
    print(f"\n[fig9] NN in-camera costs {100*(b/a-1):+.1f}% (paper: +28%) -> "
          f"offload the NN, keep the filters")

    sol = solve_cut(pipe, profiles, cal.rf_link(), regime="energy", duties=duties)
    print(f"[solver] optimal configuration: {sol.report.config_name} "
          f"at {sol.report.total_w*1e6:.1f} uW")


if __name__ == "__main__":
    main()
